//! Structured batch tracing: per-batch [`SpanRecord`]s (the five-step
//! loop's phase timings, with solve kind and per-shard slot) and
//! discrete [`EventKind`] events (admission drops, requeues, membership
//! changes, router epoch publications, accountant multiplier clamps,
//! warm-state invalidations), emitted as JSONL by a dedicated writer
//! thread behind a **bounded** channel.
//!
//! The backpressure contract — the part the tests pin — is that a batch
//! loop is *never* blocked by tracing: [`TraceSink`] uses `try_send`,
//! and when the channel is full the record is **dropped and counted**
//! (`robus_trace_dropped_total`) instead of waited on. Conservation
//! checks in `scripts/summarize_trace.py` therefore key off the `final`
//! record's counter snapshot, which survives any amount of span loss.
//!
//! Line schema (one JSON object per line, `"type"` discriminated):
//! `meta` (run shape), `span` (phase timings in ms), `event`
//! (kind/shard/tenant/value/reason), `snapshot` (periodic counter
//! dump on the run's own clock), `final` (end-of-run counter totals).

use std::io::Write;
use std::sync::Arc;
use std::thread::JoinHandle;

use crate::util::sync::mpsc;

use crate::telemetry::registry::Metrics;

/// Default bound of the writer channel (records, not bytes).
pub const DEFAULT_TRACE_CAPACITY: usize = 8192;

/// One batch step's phase breakdown: the §3.1 loop's drain → boost →
/// solve → sample → transition → execute, in host milliseconds.
/// `shard`/`slot` are `-1` on single-node drivers; `solve_kind` is
/// `"cold"`, `"warm"`, or `"off"` (warm-start disabled).
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct SpanRecord {
    /// Batch window end on the run's own clock (seconds).
    pub t: f64,
    pub batch: usize,
    pub shard: i64,
    pub slot: i64,
    pub n_queries: usize,
    pub drain_ms: f64,
    pub boost_ms: f64,
    pub solve_ms: f64,
    pub sample_ms: f64,
    pub transition_ms: f64,
    pub execute_ms: f64,
    pub solve_kind: &'static str,
}

/// Discrete trace events (each also increments its registry counter).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum EventKind {
    AdmissionDrop,
    Requeue,
    MembershipAdd,
    MembershipRemove,
    MembershipKill,
    RouterEpoch,
    MultiplierClamp,
    WarmInvalidation,
}

impl EventKind {
    pub fn name(self) -> &'static str {
        match self {
            EventKind::AdmissionDrop => "admission_drop",
            EventKind::Requeue => "requeue",
            EventKind::MembershipAdd => "membership_add",
            EventKind::MembershipRemove => "membership_remove",
            EventKind::MembershipKill => "membership_kill",
            EventKind::RouterEpoch => "router_epoch",
            EventKind::MultiplierClamp => "multiplier_clamp",
            EventKind::WarmInvalidation => "warm_invalidation",
        }
    }
}

/// Fixed-size messages to the writer thread — no heap payloads, so an
/// emit allocates nothing on the recording side.
enum TraceMsg {
    Meta {
        driver: &'static str,
        n_tenants: usize,
        n_shards: usize,
        max_boost: f64,
    },
    Span(SpanRecord),
    Event {
        t: f64,
        kind: EventKind,
        shard: i64,
        tenant: i64,
        value: f64,
        reason: &'static str,
        batch: i64,
    },
    Snapshot {
        t: f64,
        admitted: u64,
        rejected: u64,
        completed: u64,
        requeued: u64,
        queued: u64,
        live_shards: u64,
        dropped: u64,
    },
    Final {
        admitted: u64,
        rejected: u64,
        completed: u64,
        requeued: u64,
        queued: u64,
        spans: u64,
        dropped: u64,
    },
}

/// The recording half: cheap to clone (a sender + an `Arc`), shared
/// with admission-queue probes and anything else that emits off the
/// coordinator thread. Every emit is a `try_send`: accepted records
/// bump `trace_emitted`, a full channel bumps `trace_dropped`.
#[derive(Clone, Debug)]
pub struct TraceSink {
    tx: mpsc::SyncSender<TraceMsg>,
    metrics: Arc<Metrics>,
}

impl TraceSink {
    fn send(&self, msg: TraceMsg) {
        match self.tx.try_send(msg) {
            Ok(()) => self.metrics.trace_emitted.inc(),
            Err(_) => self.metrics.trace_dropped.inc(),
        }
    }

    pub fn span(&self, s: &SpanRecord) {
        self.send(TraceMsg::Span(*s));
    }

    #[allow(clippy::too_many_arguments)]
    pub fn event(
        &self,
        t: f64,
        kind: EventKind,
        shard: i64,
        tenant: i64,
        value: f64,
        reason: &'static str,
        batch: i64,
    ) {
        self.send(TraceMsg::Event {
            t,
            kind,
            shard,
            tenant,
            value,
            reason,
            batch,
        });
    }

    pub fn meta(&self, driver: &'static str, n_tenants: usize, n_shards: usize, max_boost: f64) {
        self.send(TraceMsg::Meta {
            driver,
            n_tenants,
            n_shards,
            max_boost,
        });
    }

    /// Periodic counter dump on the run's own clock (`t` in run
    /// seconds) — this is what makes the full path exercisable under a
    /// `SimClock` deterministically.
    pub fn snapshot(&self, t: f64, m: &Metrics) {
        self.send(TraceMsg::Snapshot {
            t,
            admitted: m.queries_admitted.get(),
            rejected: m.queries_rejected.get(),
            completed: m.queries_completed.get(),
            requeued: m.queries_requeued.get(),
            queued: m.queue_depth.get(),
            live_shards: m.live_shards.get(),
            dropped: m.trace_dropped.get(),
        });
    }

    /// End-of-run totals — the record `summarize_trace.py` checks its
    /// conservation invariants against.
    pub fn final_record(&self, m: &Metrics) {
        self.send(TraceMsg::Final {
            admitted: m.queries_admitted.get(),
            rejected: m.queries_rejected.get(),
            completed: m.queries_completed.get(),
            requeued: m.queries_requeued.get(),
            queued: m.queue_depth.get(),
            spans: m.batch_spans.get(),
            dropped: m.trace_dropped.get(),
        });
    }
}

/// Owns the writer thread; joining (on drop) drains whatever the
/// channel still holds and flushes the output. Drop every [`TraceSink`]
/// clone first or the join waits on the channel staying open — the
/// `Telemetry` facade owns exactly that ordering.
pub struct TraceWriter {
    handle: Option<JoinHandle<()>>,
}

impl Drop for TraceWriter {
    fn drop(&mut self) {
        if let Some(h) = self.handle.take() {
            let _ = h.join();
        }
    }
}

/// Round a millisecond figure for the wire: 1ns precision, finite.
fn ms(v: f64) -> f64 {
    if v.is_finite() {
        (v * 1e6).round() / 1e6
    } else {
        0.0
    }
}

fn format_msg(line: &mut String, msg: &TraceMsg) {
    use std::fmt::Write as _;
    line.clear();
    match msg {
        TraceMsg::Meta {
            driver,
            n_tenants,
            n_shards,
            max_boost,
        } => {
            let _ = write!(
                line,
                "{{\"type\":\"meta\",\"driver\":\"{driver}\",\"tenants\":{n_tenants},\
                 \"shards\":{n_shards},\"max_boost\":{max_boost}}}"
            );
        }
        TraceMsg::Span(s) => {
            let _ = write!(
                line,
                "{{\"type\":\"span\",\"t\":{},\"batch\":{},\"shard\":{},\"slot\":{},\
                 \"n\":{},\"drain_ms\":{},\"boost_ms\":{},\"solve_ms\":{},\
                 \"sample_ms\":{},\"transition_ms\":{},\"execute_ms\":{},\"kind\":\"{}\"}}",
                ms(s.t),
                s.batch,
                s.shard,
                s.slot,
                s.n_queries,
                ms(s.drain_ms),
                ms(s.boost_ms),
                ms(s.solve_ms),
                ms(s.sample_ms),
                ms(s.transition_ms),
                ms(s.execute_ms),
                s.solve_kind,
            );
        }
        TraceMsg::Event {
            t,
            kind,
            shard,
            tenant,
            value,
            reason,
            batch,
        } => {
            let _ = write!(
                line,
                "{{\"type\":\"event\",\"t\":{},\"kind\":\"{}\",\"shard\":{shard},\
                 \"tenant\":{tenant},\"value\":{},\"reason\":\"{reason}\",\"batch\":{batch}}}",
                ms(*t),
                kind.name(),
                ms(*value),
            );
        }
        TraceMsg::Snapshot {
            t,
            admitted,
            rejected,
            completed,
            requeued,
            queued,
            live_shards,
            dropped,
        } => {
            let _ = write!(
                line,
                "{{\"type\":\"snapshot\",\"t\":{},\"admitted\":{admitted},\
                 \"rejected\":{rejected},\"completed\":{completed},\"requeued\":{requeued},\
                 \"queued\":{queued},\"live_shards\":{live_shards},\"dropped\":{dropped}}}",
                ms(*t),
            );
        }
        TraceMsg::Final {
            admitted,
            rejected,
            completed,
            requeued,
            queued,
            spans,
            dropped,
        } => {
            let _ = write!(
                line,
                "{{\"type\":\"final\",\"admitted\":{admitted},\"rejected\":{rejected},\
                 \"completed\":{completed},\"requeued\":{requeued},\"queued\":{queued},\
                 \"spans\":{spans},\"dropped\":{dropped}}}"
            );
        }
    }
    line.push('\n');
}

/// Spawn the writer thread over `out` with a channel bound of
/// `capacity` records. Returns the recording sink and the thread
/// handle; the thread exits when every sink clone has dropped.
pub fn spawn_writer(
    mut out: Box<dyn Write + Send>,
    capacity: usize,
    metrics: Arc<Metrics>,
) -> (TraceSink, TraceWriter) {
    let (tx, rx) = mpsc::sync_channel::<TraceMsg>(capacity.max(1));
    let handle = std::thread::Builder::new()
        .name("robus-trace".into())
        .spawn(move || {
            let mut line = String::with_capacity(256);
            while let Ok(msg) = rx.recv() {
                format_msg(&mut line, &msg);
                if out.write_all(line.as_bytes()).is_err() {
                    break;
                }
            }
            let _ = out.flush();
        })
        .expect("spawn trace writer thread");
    (
        TraceSink { tx, metrics },
        TraceWriter {
            handle: Some(handle),
        },
    )
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::Mutex;

    /// A `Write` that appends into shared memory.
    struct SharedBuf(Arc<Mutex<Vec<u8>>>);

    impl Write for SharedBuf {
        fn write(&mut self, buf: &[u8]) -> std::io::Result<usize> {
            self.0.lock().unwrap().extend_from_slice(buf);
            Ok(buf.len())
        }

        fn flush(&mut self) -> std::io::Result<()> {
            Ok(())
        }
    }

    fn span(batch: usize) -> SpanRecord {
        SpanRecord {
            t: (batch + 1) as f64 * 0.25,
            batch,
            shard: 2,
            slot: 0,
            n_queries: 10,
            drain_ms: 0.5,
            boost_ms: 0.0,
            solve_ms: 3.25,
            sample_ms: 0.125,
            transition_ms: 0.25,
            execute_ms: 1.0,
            solve_kind: "warm",
        }
    }

    #[test]
    fn writer_emits_jsonl_in_order() {
        let buf = Arc::new(Mutex::new(Vec::new()));
        let metrics = Arc::new(Metrics::new());
        let (sink, writer) = spawn_writer(Box::new(SharedBuf(buf.clone())), 64, metrics.clone());
        sink.meta("test", 3, 2, 4.0);
        sink.span(&span(0));
        sink.event(0.25, EventKind::RouterEpoch, -1, -1, 1.0, "sync", 0);
        sink.final_record(&metrics);
        drop(sink);
        drop(writer); // joins; everything queued is written

        let text = String::from_utf8(buf.lock().unwrap().clone()).unwrap();
        let lines: Vec<&str> = text.lines().collect();
        assert_eq!(lines.len(), 4);
        assert!(lines[0].contains("\"type\":\"meta\""));
        assert!(lines[0].contains("\"max_boost\":4"));
        assert!(lines[1].contains("\"type\":\"span\""));
        assert!(lines[1].contains("\"solve_ms\":3.25"));
        assert!(lines[1].contains("\"kind\":\"warm\""));
        assert!(lines[2].contains("\"kind\":\"router_epoch\""));
        assert!(lines[3].contains("\"type\":\"final\""));
        assert_eq!(metrics.trace_emitted.get(), 4);
        assert_eq!(metrics.trace_dropped.get(), 0);
        // Every line parses as the crate's own JSON dialect.
        for l in &lines {
            crate::util::json::Json::parse(l).expect("trace line is valid JSON");
        }
    }

    #[test]
    // Relies on a wall-clock sleeper and deliberately leaks its writer
    // thread — excluded from the Miri subset (thread-leak detection);
    // the drop-and-count protocol itself is pinned for every
    // interleaving by `rust/tests/model_concurrency.rs`.
    #[cfg_attr(miri, ignore)]
    fn full_channel_drops_and_counts() {
        // A writer that never makes progress: the channel fills and
        // every further emit must drop, not block.
        struct Stuck;
        impl Write for Stuck {
            fn write(&mut self, _buf: &[u8]) -> std::io::Result<usize> {
                std::thread::sleep(std::time::Duration::from_secs(3600));
                Ok(0)
            }
            fn flush(&mut self) -> std::io::Result<()> {
                Ok(())
            }
        }
        let metrics = Arc::new(Metrics::new());
        let (sink, writer) = spawn_writer(Box::new(Stuck), 2, metrics.clone());
        for b in 0..50 {
            sink.span(&span(b));
        }
        assert_eq!(metrics.trace_emitted.get() + metrics.trace_dropped.get(), 50);
        assert!(metrics.trace_dropped.get() > 0, "bounded channel never dropped");
        drop(sink);
        // Leak the writer thread instead of joining a sleeper: the
        // facade never wedges like this (its writers always drain), the
        // stuck writer exists only to prove emits cannot block.
        std::mem::forget(writer);
    }
}
