//! Zero-overhead observability for the serving stack (DESIGN.md §2h).
//!
//! Four pieces, all dependency-free:
//! - [`registry`] — lock-free atomic counters/gauges and fixed-bucket
//!   log-scale histograms; hot-path `record` is alloc-free and
//!   wait-free.
//! - [`trace`] — per-batch span records and discrete events as JSONL
//!   through a bounded writer thread that drops-and-counts under
//!   backpressure (`--trace-out FILE`).
//! - [`endpoint`] — a live Prometheus text-exposition `/metrics`
//!   server on `std::net::TcpListener` (`--metrics-addr HOST:PORT`).
//! - [`snapshot`] — clock-generic periodic counter snapshots so
//!   SimClock tests drive the full path deterministically.
//!
//! The [`Telemetry`] facade bundles them behind one `&Telemetry`
//! threaded through every driver. The hard invariant, pinned by
//! `rust/tests/telemetry_observer.rs`: telemetry is a **pure
//! observer** — it consumes no randomness, takes no locks on the batch
//! path, and never changes control flow, so a SimClock replay is
//! bit-identical with telemetry on versus off at any shard/worker
//! count.

pub mod endpoint;
pub mod registry;
pub mod snapshot;
pub mod trace;

use std::io::Write;
use std::net::SocketAddr;
use std::sync::Arc;

pub use endpoint::MetricsEndpoint;
pub use registry::{Counter, Gauge, Histogram, LocalHistogram, Metrics};
pub use snapshot::SnapshotTimer;
pub use trace::{spawn_writer, EventKind, SpanRecord, TraceSink, TraceWriter};
pub use trace::DEFAULT_TRACE_CAPACITY;

/// The per-run observability handle. `Telemetry::off()` is free —
/// counters still count (they're a handful of relaxed atomics) but no
/// trace writer, endpoint, or snapshot timer exists. All drivers take
/// `&Telemetry`; it is `Sync`, so scoped shard threads share it
/// directly.
pub struct Telemetry {
    metrics: Arc<Metrics>,
    sink: Option<TraceSink>,
    writer: Option<TraceWriter>,
    endpoint: Option<MetricsEndpoint>,
    snap: SnapshotTimer,
}

impl Default for Telemetry {
    fn default() -> Telemetry {
        Telemetry::off()
    }
}

impl Telemetry {
    /// No tracing, no endpoint, no snapshots — just the registry.
    pub fn off() -> Telemetry {
        Telemetry {
            metrics: Arc::new(Metrics::new()),
            sink: None,
            writer: None,
            endpoint: None,
            snap: SnapshotTimer::new(0.0),
        }
    }

    pub fn metrics(&self) -> &Metrics {
        &self.metrics
    }

    pub fn metrics_arc(&self) -> Arc<Metrics> {
        self.metrics.clone()
    }

    /// Whether a trace sink is attached (spans/events leave the
    /// process).
    pub fn tracing(&self) -> bool {
        self.sink.is_some()
    }

    /// Attach a JSONL trace writer over an arbitrary sink (tests use
    /// in-memory buffers; `io::sink()` gives a full-path no-op).
    pub fn trace_to(&mut self, out: Box<dyn Write + Send>, capacity: usize) {
        let (sink, writer) = spawn_writer(out, capacity, self.metrics.clone());
        self.sink = Some(sink);
        self.writer = Some(writer);
    }

    /// Attach a trace writer over `path`. Creating the file here —
    /// before any run starts — is the flag-hygiene contract: an
    /// unwritable `--trace-out` is a startup error.
    pub fn trace_to_file(&mut self, path: &str) -> std::io::Result<()> {
        let file = std::fs::File::create(path)?;
        self.trace_to(
            Box::new(std::io::BufWriter::new(file)),
            DEFAULT_TRACE_CAPACITY,
        );
        Ok(())
    }

    /// Bind the live `/metrics` endpoint. Errors (unbindable address,
    /// bad syntax) surface here, at startup.
    pub fn serve_metrics(&mut self, addr: &str) -> std::io::Result<SocketAddr> {
        let ep = MetricsEndpoint::bind(addr, self.metrics.clone())?;
        let bound = ep.addr();
        self.endpoint = Some(ep);
        Ok(bound)
    }

    /// Emit a counter snapshot into the trace every `secs` of *run*
    /// clock (SimClock or real).
    pub fn snapshot_every(&mut self, secs: f64) {
        self.snap = SnapshotTimer::new(secs);
    }

    /// Run-shape header, first line of a trace.
    pub fn meta(&self, driver: &'static str, n_tenants: usize, n_shards: usize, max_boost: f64) {
        if let Some(sink) = &self.sink {
            sink.meta(driver, n_tenants, n_shards, max_boost);
        }
    }

    /// Record one batch step's phase breakdown: registry histograms +
    /// counters always, trace span when a sink is attached.
    pub fn span(&self, s: &SpanRecord) {
        let m = &self.metrics;
        m.batch_spans.inc();
        m.queries_completed.add(s.n_queries as u64);
        m.solve_ms.record(s.solve_ms);
        m.batch_queries.record(s.n_queries as f64);
        match s.solve_kind {
            "warm" => m.solves_warm.inc(),
            "cold" => m.solves_cold.inc(),
            _ => {}
        }
        if let Some(sink) = &self.sink {
            sink.span(s);
        }
    }

    /// Record a discrete event: bumps the matching counter, emits a
    /// trace event when a sink is attached. Use `-1` for
    /// not-applicable `shard`/`tenant`/`batch`.
    #[allow(clippy::too_many_arguments)]
    pub fn event(
        &self,
        t: f64,
        kind: EventKind,
        shard: i64,
        tenant: i64,
        value: f64,
        reason: &'static str,
        batch: i64,
    ) {
        let m = &self.metrics;
        match kind {
            EventKind::AdmissionDrop => m.queries_rejected.inc(),
            EventKind::Requeue => m.queries_requeued.inc(),
            EventKind::MembershipAdd => m.membership_adds.inc(),
            EventKind::MembershipRemove => m.membership_removes.inc(),
            EventKind::MembershipKill => m.membership_kills.inc(),
            EventKind::RouterEpoch => m.router_epochs.inc(),
            EventKind::MultiplierClamp => m.multiplier_clamps.inc(),
            EventKind::WarmInvalidation => m.warm_invalidations.inc(),
        }
        if let Some(sink) = &self.sink {
            sink.event(t, kind, shard, tenant, value, reason, batch);
        }
    }

    /// Record one query's admission wait (milliseconds).
    pub fn admit_wait(&self, wait_ms: f64) {
        self.metrics.admit_wait_ms.record(wait_ms);
    }

    /// Periodic heartbeat from a driver loop: emits a counter snapshot
    /// into the trace when one is due on the run's clock.
    pub fn tick(&self, now: f64) {
        if self.snap.due(now) {
            if let Some(sink) = &self.sink {
                sink.snapshot(now, &self.metrics);
            }
        }
    }

    /// A cheap clone-able handle for admission queues (and their
    /// producer threads): counts admits/rejects/requeues and emits
    /// drop/requeue events without the queue knowing about `Telemetry`.
    pub fn queue_probe(&self, shard: i64) -> QueueProbe {
        QueueProbe {
            metrics: self.metrics.clone(),
            sink: self.sink.clone(),
            shard,
        }
    }

    /// Flush and tear down: writes the `final` conservation record,
    /// drops the sink (closing the channel), joins the writer thread,
    /// and stops the endpoint. Called automatically on drop; callable
    /// early to flush before reading the trace file. Must run after
    /// every [`QueueProbe`] from this telemetry has been dropped, or
    /// the writer join waits on their open channel handles — drivers
    /// satisfy this by construction (queues die when the run returns).
    pub fn shutdown(&mut self) {
        if let Some(sink) = self.sink.take() {
            sink.final_record(&self.metrics);
        }
        self.writer.take();
        self.endpoint.take();
    }
}

impl Drop for Telemetry {
    fn drop(&mut self) {
        self.shutdown();
    }
}

/// Admission-side probe handed to `AdmissionQueue`s; see
/// [`Telemetry::queue_probe`].
#[derive(Clone, Debug)]
pub struct QueueProbe {
    metrics: Arc<Metrics>,
    sink: Option<TraceSink>,
    shard: i64,
}

impl QueueProbe {
    /// A probe wired to nothing — the default inside queues built
    /// without telemetry.
    pub fn disconnected() -> QueueProbe {
        QueueProbe {
            metrics: Arc::new(Metrics::new()),
            sink: None,
            shard: -1,
        }
    }

    pub fn admitted(&self) {
        self.metrics.queries_admitted.inc();
    }

    pub fn rejected(&self, tenant: usize, arrival: f64) {
        self.metrics.queries_rejected.inc();
        if let Some(sink) = &self.sink {
            sink.event(
                arrival,
                EventKind::AdmissionDrop,
                self.shard,
                tenant as i64,
                0.0,
                "queue_full",
                -1,
            );
        }
    }

    pub fn requeued(&self, tenant: usize, arrival: f64) {
        self.metrics.queries_requeued.inc();
        if let Some(sink) = &self.sink {
            sink.event(
                arrival,
                EventKind::Requeue,
                self.shard,
                tenant as i64,
                0.0,
                "drain_rehome",
                -1,
            );
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::Mutex;

    struct SharedBuf(Arc<Mutex<Vec<u8>>>);

    impl Write for SharedBuf {
        fn write(&mut self, buf: &[u8]) -> std::io::Result<usize> {
            self.0.lock().unwrap().extend_from_slice(buf);
            Ok(buf.len())
        }

        fn flush(&mut self) -> std::io::Result<()> {
            Ok(())
        }
    }

    #[test]
    fn off_telemetry_records_metrics_only() {
        let tel = Telemetry::off();
        assert!(!tel.tracing());
        tel.span(&SpanRecord {
            t: 0.25,
            batch: 0,
            shard: -1,
            slot: -1,
            n_queries: 7,
            drain_ms: 0.0,
            boost_ms: 0.0,
            solve_ms: 2.0,
            sample_ms: 0.0,
            transition_ms: 0.0,
            execute_ms: 0.5,
            solve_kind: "cold",
        });
        tel.event(0.3, EventKind::RouterEpoch, -1, -1, 1.0, "sync", 0);
        assert_eq!(tel.metrics().batch_spans.get(), 1);
        assert_eq!(tel.metrics().queries_completed.get(), 7);
        assert_eq!(tel.metrics().solves_cold.get(), 1);
        assert_eq!(tel.metrics().router_epochs.get(), 1);
        assert_eq!(tel.metrics().trace_emitted.get(), 0);
    }

    #[test]
    fn facade_trace_lifecycle_writes_final_record() {
        let buf = Arc::new(Mutex::new(Vec::new()));
        let mut tel = Telemetry::off();
        tel.trace_to(Box::new(SharedBuf(buf.clone())), 128);
        tel.snapshot_every(1.0);
        tel.meta("run", 2, 1, 4.0);
        let probe = tel.queue_probe(0);
        probe.admitted();
        probe.rejected(1, 0.5);
        probe.requeued(0, 0.75);
        tel.tick(0.0); // first snapshot due immediately
        tel.tick(0.5); // not due
        drop(probe); // release the probe's sink clone before shutdown
        tel.shutdown();

        let text = String::from_utf8(buf.lock().unwrap().clone()).unwrap();
        let lines: Vec<&str> = text.lines().collect();
        assert!(lines[0].contains("\"type\":\"meta\""));
        assert_eq!(
            lines.iter().filter(|l| l.contains("\"type\":\"snapshot\"")).count(),
            1
        );
        let last = lines.last().unwrap();
        assert!(last.contains("\"type\":\"final\""), "got: {last}");
        assert!(last.contains("\"admitted\":1"));
        assert!(last.contains("\"rejected\":1"));
        assert!(last.contains("\"requeued\":1"));
        assert_eq!(tel.metrics().queries_admitted.get(), 1);
        // Shutdown is idempotent.
        tel.shutdown();
    }

    #[test]
    fn snapshot_timer_rides_sim_clock_times() {
        let buf = Arc::new(Mutex::new(Vec::new()));
        let mut tel = Telemetry::off();
        tel.trace_to(Box::new(SharedBuf(buf.clone())), 128);
        tel.snapshot_every(0.5);
        for i in 0..8 {
            tel.tick(i as f64 * 0.25); // 0.0, 0.25, ..., 1.75
        }
        tel.shutdown();
        let text = String::from_utf8(buf.lock().unwrap().clone()).unwrap();
        let snaps = text
            .lines()
            .filter(|l| l.contains("\"type\":\"snapshot\""))
            .count();
        assert_eq!(snaps, 4, "0.0, 0.5, 1.0, 1.5 due under a 0.5s period");
    }
}
