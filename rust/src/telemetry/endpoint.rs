//! Live `/metrics` endpoint: a `std::net::TcpListener` accept loop on
//! its own thread, answering every HTTP request with the registry
//! rendered as Prometheus text exposition (version 0.0.4). Zero
//! external crates; the "HTTP server" is deliberately minimal — read
//! until the blank line, write one `Connection: close` response.
//!
//! Binding happens in [`MetricsEndpoint::bind`], *before* any run
//! starts, so an unbindable `--metrics-addr` is a startup error rather
//! than a mid-run surprise (flag-hygiene contract).

use std::io::{Read, Write};
use std::net::{SocketAddr, TcpListener, TcpStream};
use std::sync::Arc;
use std::thread::JoinHandle;
use std::time::Duration;

use crate::telemetry::registry::Metrics;
use crate::util::sync::atomic::{AtomicBool, Ordering};

pub struct MetricsEndpoint {
    addr: SocketAddr,
    stop: Arc<AtomicBool>,
    handle: Option<JoinHandle<()>>,
}

impl MetricsEndpoint {
    /// Bind `addr` (e.g. `127.0.0.1:9101`; port 0 picks a free port)
    /// and start serving `metrics`. Errors here are the caller's
    /// startup errors.
    pub fn bind(addr: &str, metrics: Arc<Metrics>) -> std::io::Result<MetricsEndpoint> {
        let listener = TcpListener::bind(addr)?;
        let local = listener.local_addr()?;
        let stop = Arc::new(AtomicBool::new(false));
        let stop_in = stop.clone();
        let handle = std::thread::Builder::new()
            .name("robus-metrics".into())
            .spawn(move || {
                for conn in listener.incoming() {
                    // ordering: Acquire pairs with the Release store in
                    // Drop — kept at Acquire/Release in the PR 9 audit:
                    // observing `stop` must also make everything the
                    // dropping thread did before shutdown visible here,
                    // so the loop never serves a response derived from
                    // a half-torn-down owner.
                    if stop_in.load(Ordering::Acquire) {
                        break;
                    }
                    if let Ok(stream) = conn {
                        // One slow scraper must not wedge the accept
                        // loop forever.
                        let _ = stream.set_read_timeout(Some(Duration::from_secs(2)));
                        let _ = stream.set_write_timeout(Some(Duration::from_secs(2)));
                        let _ = serve_one(stream, &metrics);
                    }
                }
            })?;
        Ok(MetricsEndpoint {
            addr: local,
            stop,
            handle: Some(handle),
        })
    }

    /// The actually-bound address (resolves port 0).
    pub fn addr(&self) -> SocketAddr {
        self.addr
    }
}

impl Drop for MetricsEndpoint {
    fn drop(&mut self) {
        // ordering: Release pairs with the Acquire load in the accept
        // loop (see the comment there).
        self.stop.store(true, Ordering::Release);
        // `incoming()` blocks in accept; poke it awake so the thread
        // observes the stop flag and exits.
        let _ = TcpStream::connect(self.addr);
        if let Some(h) = self.handle.take() {
            let _ = h.join();
        }
    }
}

/// Read the request head (best effort), respond with the current
/// exposition. Any request path gets the same body — there is exactly
/// one resource.
fn serve_one(mut stream: TcpStream, metrics: &Metrics) -> std::io::Result<()> {
    let mut head = [0u8; 1024];
    let mut read = 0;
    // Read until CRLFCRLF, EOF, buffer full, or timeout: enough to
    // consume a scraper's GET line + headers without trusting it.
    loop {
        match stream.read(&mut head[read..]) {
            Ok(0) => break,
            Ok(n) => {
                read += n;
                if head[..read].windows(4).any(|w| w == b"\r\n\r\n") || read == head.len() {
                    break;
                }
            }
            Err(_) => break,
        }
    }
    let body = metrics.render_prometheus();
    let resp = format!(
        "HTTP/1.1 200 OK\r\nContent-Type: text/plain; version=0.0.4\r\n\
         Content-Length: {}\r\nConnection: close\r\n\r\n{}",
        body.len(),
        body
    );
    stream.write_all(resp.as_bytes())?;
    stream.flush()
}

#[cfg(test)]
mod tests {
    use super::*;

    // Both tests open real sockets — unsupported under the Miri
    // interpreter, so they sit outside the Miri subset.
    #[test]
    #[cfg_attr(miri, ignore)]
    fn bind_serve_scrape_shutdown() {
        let metrics = Arc::new(Metrics::new());
        metrics.queries_admitted.add(42);
        metrics.solve_ms.record(1.5);
        let ep = MetricsEndpoint::bind("127.0.0.1:0", metrics).expect("bind ephemeral port");
        let addr = ep.addr();

        let mut stream = TcpStream::connect(addr).expect("connect");
        stream
            .write_all(b"GET /metrics HTTP/1.1\r\nHost: x\r\n\r\n")
            .unwrap();
        let mut resp = String::new();
        stream.read_to_string(&mut resp).expect("read response");
        assert!(resp.starts_with("HTTP/1.1 200 OK"), "got: {resp}");
        assert!(resp.contains("text/plain; version=0.0.4"));
        assert!(resp.contains("robus_queries_admitted_total 42"));
        assert!(resp.contains("robus_solve_ms_count 1"));
        drop(ep); // joins the accept thread

        // After shutdown the port stops answering (connect may still
        // succeed briefly on some stacks; a second bind must work).
        let rebind = TcpListener::bind(addr);
        assert!(rebind.is_ok(), "address not released after drop");
    }

    #[test]
    #[cfg_attr(miri, ignore)]
    fn unbindable_address_errors_at_bind() {
        let metrics = Arc::new(Metrics::new());
        assert!(MetricsEndpoint::bind("256.0.0.1:80", metrics.clone()).is_err());
        assert!(MetricsEndpoint::bind("not-an-addr", metrics).is_err());
    }
}
