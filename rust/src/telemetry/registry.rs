//! The lock-free metrics registry: atomic [`Counter`]s and [`Gauge`]s
//! plus fixed-bucket log-scale [`Histogram`]s whose buckets are
//! pre-allocated at construction, so every hot-path `record` call is
//! **alloc-free and wait-free** (a `partition_point` over 256 cached
//! bounds and three `Relaxed` `fetch_add`s). This is what lets the
//! telemetry layer ride inside the zero-alloc steady-state batch loops
//! (DESIGN.md §2g) without becoming a participant in them.
//!
//! Histograms use a geometric bucket ladder: 256 buckets growing by
//! 2^(1/8) ≈ 1.09× per bucket from an upper bound of 10⁻³ on the first,
//! covering ~10⁻³ … 3.6×10⁶ with ≤9% relative quantile error — wide
//! enough for millisecond latencies (up to ~an hour) and batch sizes on
//! one shared layout. [`LocalHistogram`] is the single-threaded,
//! mergeable twin used by streaming run summaries
//! (`coordinator::loop_::ExecSummary`), sharing the bucket math so
//! quantiles agree between the live registry and end-of-run reports.

use crate::util::sync::atomic::{AtomicU64, Ordering};

/// Geometric buckets per histogram.
pub const N_BUCKETS: usize = 256;
/// Upper bound of the first bucket.
const LO: f64 = 1e-3;
/// log₂ of the per-bucket growth ratio (2^(1/8) ≈ 1.0905).
const STEP_LOG2: f64 = 0.125;

/// Upper bounds of buckets `0..N_BUCKETS`; the last is a catch-all
/// (rendered as `+Inf` in the Prometheus exposition).
fn bucket_bounds() -> Box<[f64]> {
    (0..N_BUCKETS)
        .map(|i| LO * (i as f64 * STEP_LOG2).exp2())
        .collect()
}

/// Bucket index for `v`: bucket `i` covers `(bounds[i-1], bounds[i]]`,
/// bucket 0 everything `<= bounds[0]`, the last bucket everything else.
fn bucket_index(bounds: &[f64], v: f64) -> usize {
    let v = if v.is_finite() && v > 0.0 { v } else { 0.0 };
    bounds.partition_point(|&b| b < v).min(N_BUCKETS - 1)
}

/// Point estimate for a value inside bucket `i`: the geometric midpoint
/// of the bucket (`upper / 2^(1/16)`), biased at most one ratio step
/// from any sample the bucket absorbed.
fn bucket_estimate(bounds: &[f64], i: usize) -> f64 {
    bounds[i] * (-STEP_LOG2 / 2.0).exp2()
}

/// Rank-walk quantile over a bucket snapshot (`q` in percent).
fn quantile_over(bounds: &[f64], buckets: &[u64], count: u64, q: f64) -> f64 {
    if count == 0 {
        return 0.0;
    }
    let target = ((q / 100.0).clamp(0.0, 1.0) * (count - 1) as f64).round() as u64;
    let mut cum = 0u64;
    for (i, &b) in buckets.iter().enumerate() {
        cum += b;
        if cum > target {
            return bucket_estimate(bounds, i);
        }
    }
    bucket_estimate(bounds, N_BUCKETS - 1)
}

/// A monotone event counter. All operations are `Relaxed` atomics: the
/// registry observes, it never synchronizes.
#[derive(Debug, Default)]
pub struct Counter(AtomicU64);

impl Counter {
    pub const fn new() -> Self {
        Self(AtomicU64::new(0))
    }

    pub fn inc(&self) {
        // ordering: Relaxed pairs with the Relaxed `get` — a monotone
        // event count, observed but never used to order other data.
        self.0.fetch_add(1, Ordering::Relaxed);
    }

    pub fn add(&self, n: u64) {
        // ordering: Relaxed pairs with the Relaxed `get` (see `inc`).
        self.0.fetch_add(n, Ordering::Relaxed);
    }

    pub fn get(&self) -> u64 {
        // ordering: Relaxed pairs with the Relaxed `inc`/`add` writers.
        self.0.load(Ordering::Relaxed)
    }
}

/// A last-writer-wins instantaneous value (queue depth, live shards).
#[derive(Debug, Default)]
pub struct Gauge(AtomicU64);

impl Gauge {
    pub const fn new() -> Self {
        Self(AtomicU64::new(0))
    }

    pub fn set(&self, v: u64) {
        // ordering: Relaxed pairs with the Relaxed `get` — last-writer-
        // wins observability value, no data published through it.
        self.0.store(v, Ordering::Relaxed);
    }

    pub fn get(&self) -> u64 {
        // ordering: Relaxed pairs with the Relaxed `set` writer.
        self.0.load(Ordering::Relaxed)
    }
}

/// A thread-safe fixed-bucket log-scale histogram. `record` is
/// wait-free and alloc-free; `quantile` and the Prometheus rendering
/// take a racy-but-consistent-enough snapshot (each bucket is loaded
/// once, `Relaxed` — fine for observability, never for control flow).
#[derive(Debug)]
pub struct Histogram {
    bounds: Box<[f64]>,
    buckets: Box<[AtomicU64]>,
    count: AtomicU64,
    /// Sum in micro-units (`v * 1e6` truncated): an integer so it can
    /// be a single wait-free `fetch_add` instead of a CAS loop on bits.
    sum_micro: AtomicU64,
}

impl Histogram {
    pub fn new() -> Self {
        Self {
            bounds: bucket_bounds(),
            buckets: (0..N_BUCKETS).map(|_| AtomicU64::new(0)).collect(),
            count: AtomicU64::new(0),
            sum_micro: AtomicU64::new(0),
        }
    }

    pub fn record(&self, v: f64) {
        let i = bucket_index(&self.bounds, v);
        // ordering: Relaxed pairs with the Relaxed reader loads in
        // `count`/`sum`/`quantile`/`render_into`; the three adds are
        // individually atomic but deliberately not a transaction — a
        // concurrent render may see a record half-applied, which is
        // fine for observability (pinned by the concurrent stress test
        // below: totals converge once writers finish).
        self.buckets[i].fetch_add(1, Ordering::Relaxed);
        self.count.fetch_add(1, Ordering::Relaxed);
        let v = if v.is_finite() && v > 0.0 { v } else { 0.0 };
        self.sum_micro.fetch_add((v * 1e6) as u64, Ordering::Relaxed);
    }

    pub fn count(&self) -> u64 {
        // ordering: Relaxed pairs with the Relaxed writers in `record`.
        self.count.load(Ordering::Relaxed)
    }

    pub fn sum(&self) -> f64 {
        // ordering: Relaxed pairs with the Relaxed writers in `record`.
        self.sum_micro.load(Ordering::Relaxed) as f64 / 1e6
    }

    /// Estimated `q`-th percentile (≤ one bucket-ratio of relative
    /// error vs the exact sample percentile; see the property test in
    /// `rust/tests/telemetry_observer.rs`).
    pub fn quantile(&self, q: f64) -> f64 {
        // ordering: Relaxed pairs with the Relaxed writers in `record`
        // (racy-but-consistent-enough snapshot; see the type docs).
        let buckets: Vec<u64> = self
            .buckets
            .iter()
            .map(|b| b.load(Ordering::Relaxed))
            .collect();
        let count: u64 = buckets.iter().sum();
        quantile_over(&self.bounds, &buckets, count, q)
    }

    /// Append this histogram in Prometheus text exposition (cumulative
    /// `le` series over the non-empty buckets, then `+Inf`/sum/count).
    fn render_into(&self, name: &str, out: &mut String) {
        use std::fmt::Write as _;
        let _ = writeln!(out, "# TYPE {name} histogram");
        let mut cum = 0u64;
        for (i, b) in self.buckets.iter().enumerate() {
            // ordering: Relaxed pairs with the Relaxed writers in
            // `record`; the exposition derives count from the same
            // bucket loads so the cumulative series stays coherent.
            let n = b.load(Ordering::Relaxed);
            if n == 0 {
                continue;
            }
            cum += n;
            if i + 1 < N_BUCKETS {
                let _ = writeln!(out, "{name}_bucket{{le=\"{}\"}} {cum}", self.bounds[i]);
            }
        }
        let _ = writeln!(out, "{name}_bucket{{le=\"+Inf\"}} {cum}");
        let _ = writeln!(out, "{name}_sum {}", self.sum());
        let _ = writeln!(out, "{name}_count {cum}");
    }
}

impl Default for Histogram {
    fn default() -> Self {
        Self::new()
    }
}

/// The single-threaded, mergeable twin of [`Histogram`]: same bucket
/// ladder, plain `u64` buckets, `Clone`. This is what streaming run
/// summaries carry so long `robus serve` runs stop retaining every raw
/// per-batch solve sample just to print two end-of-run percentiles.
#[derive(Debug, Clone)]
pub struct LocalHistogram {
    bounds: Box<[f64]>,
    buckets: Box<[u64]>,
    count: u64,
    sum: f64,
}

impl LocalHistogram {
    pub fn new() -> Self {
        Self {
            bounds: bucket_bounds(),
            buckets: vec![0u64; N_BUCKETS].into_boxed_slice(),
            count: 0,
            sum: 0.0,
        }
    }

    pub fn record(&mut self, v: f64) {
        let i = bucket_index(&self.bounds, v);
        self.buckets[i] += 1;
        self.count += 1;
        if v.is_finite() && v > 0.0 {
            self.sum += v;
        }
    }

    /// Fold `other` into `self` (the federation's shard-summary merge).
    pub fn merge(&mut self, other: &LocalHistogram) {
        for (a, b) in self.buckets.iter_mut().zip(other.buckets.iter()) {
            *a += *b;
        }
        self.count += other.count;
        self.sum += other.sum;
    }

    pub fn count(&self) -> u64 {
        self.count
    }

    pub fn sum(&self) -> f64 {
        self.sum
    }

    pub fn quantile(&self, q: f64) -> f64 {
        quantile_over(&self.bounds, &self.buckets, self.count, q)
    }
}

impl Default for LocalHistogram {
    fn default() -> Self {
        Self::new()
    }
}

/// The process-wide registry: every named series the serving stack
/// records and the `/metrics` endpoint exposes. One flat struct of
/// atomics — registration is the field list, lookup is field access,
/// and there is nothing to lock, ever.
#[derive(Debug, Default)]
pub struct Metrics {
    /// Per-(shard,)batch step spans recorded (one per `SpanRecord`).
    pub batch_spans: Counter,
    pub queries_admitted: Counter,
    pub queries_rejected: Counter,
    pub queries_completed: Counter,
    /// Already-admitted queries re-homed by a drain (never re-counted
    /// as admissions).
    pub queries_requeued: Counter,
    pub solves_cold: Counter,
    pub solves_warm: Counter,
    pub membership_adds: Counter,
    pub membership_removes: Counter,
    pub membership_kills: Counter,
    /// Router epochs published (RCU pointer swaps in `ServeRouter`).
    pub router_epochs: Counter,
    /// Arrivals routed through the documented shard-0 fallback because
    /// the epoch's shard set did not contain the view's home shard
    /// (`ServeRouter::idx` miss — should stay 0 outside membership
    /// transitions).
    pub router_fallback_routes: Counter,
    /// Per-tenant accountant multipliers that hit the `max_boost` clamp.
    pub multiplier_clamps: Counter,
    pub warm_invalidations: Counter,
    /// Trace records accepted by the bounded writer channel…
    pub trace_emitted: Counter,
    /// …and records dropped because it was full (never blocks a loop).
    pub trace_dropped: Counter,
    /// Backlog across admission queues at the last cut.
    pub queue_depth: Gauge,
    pub live_shards: Gauge,
    pub solve_ms: Histogram,
    pub admit_wait_ms: Histogram,
    /// Queries per batch cut (distribution of batch sizes).
    pub batch_queries: Histogram,
}

impl Metrics {
    pub fn new() -> Self {
        Self::default()
    }

    fn counters(&self) -> [(&'static str, &Counter); 16] {
        [
            ("robus_batch_spans_total", &self.batch_spans),
            ("robus_queries_admitted_total", &self.queries_admitted),
            ("robus_queries_rejected_total", &self.queries_rejected),
            ("robus_queries_completed_total", &self.queries_completed),
            ("robus_queries_requeued_total", &self.queries_requeued),
            ("robus_solves_cold_total", &self.solves_cold),
            ("robus_solves_warm_total", &self.solves_warm),
            ("robus_membership_adds_total", &self.membership_adds),
            ("robus_membership_removes_total", &self.membership_removes),
            ("robus_membership_kills_total", &self.membership_kills),
            ("robus_router_epochs_total", &self.router_epochs),
            ("robus_router_fallback_routes_total", &self.router_fallback_routes),
            ("robus_multiplier_clamps_total", &self.multiplier_clamps),
            ("robus_warm_invalidations_total", &self.warm_invalidations),
            ("robus_trace_emitted_total", &self.trace_emitted),
            ("robus_trace_dropped_total", &self.trace_dropped),
        ]
    }

    fn gauges(&self) -> [(&'static str, &Gauge); 2] {
        [
            ("robus_queue_depth", &self.queue_depth),
            ("robus_live_shards", &self.live_shards),
        ]
    }

    fn histograms(&self) -> [(&'static str, &Histogram); 3] {
        [
            ("robus_solve_ms", &self.solve_ms),
            ("robus_admit_wait_ms", &self.admit_wait_ms),
            ("robus_batch_queries", &self.batch_queries),
        ]
    }

    /// Prometheus text exposition (format 0.0.4) of every series.
    pub fn render_prometheus(&self) -> String {
        use std::fmt::Write as _;
        let mut out = String::with_capacity(4096);
        for (name, c) in self.counters() {
            let _ = writeln!(out, "# TYPE {name} counter");
            let _ = writeln!(out, "{name} {}", c.get());
        }
        for (name, g) in self.gauges() {
            let _ = writeln!(out, "# TYPE {name} gauge");
            let _ = writeln!(out, "{name} {}", g.get());
        }
        for (name, h) in self.histograms() {
            h.render_into(name, &mut out);
        }
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn counter_and_gauge_roundtrip() {
        let c = Counter::new();
        c.inc();
        c.add(4);
        assert_eq!(c.get(), 5);
        let g = Gauge::new();
        g.set(17);
        assert_eq!(g.get(), 17);
        g.set(3);
        assert_eq!(g.get(), 3);
    }

    #[test]
    fn bucket_index_bounds() {
        let bounds = bucket_bounds();
        assert_eq!(bucket_index(&bounds, 0.0), 0);
        assert_eq!(bucket_index(&bounds, -5.0), 0);
        assert_eq!(bucket_index(&bounds, f64::NAN), 0);
        assert_eq!(bucket_index(&bounds, LO), 0);
        assert_eq!(bucket_index(&bounds, LO * 1.01), 1);
        // Everything past the ladder lands in the catch-all.
        assert_eq!(bucket_index(&bounds, 1e12), N_BUCKETS - 1);
        // Bounds are strictly increasing (partition_point's contract).
        assert!(bounds.windows(2).all(|w| w[0] < w[1]));
    }

    #[test]
    fn histogram_quantiles_track_recorded_values() {
        let h = Histogram::new();
        for _ in 0..90 {
            h.record(10.0);
        }
        for _ in 0..10 {
            h.record(1000.0);
        }
        assert_eq!(h.count(), 100);
        assert!((h.sum() - 10.0 * 90.0 - 1000.0 * 10.0).abs() < 1e-3);
        let ratio = (STEP_LOG2).exp2();
        let p50 = h.quantile(50.0);
        assert!(p50 >= 10.0 / ratio && p50 <= 10.0 * ratio, "p50={p50}");
        let p99 = h.quantile(99.0);
        assert!(p99 >= 1000.0 / ratio && p99 <= 1000.0 * ratio, "p99={p99}");
        // Empty histogram: a defined zero, not NaN.
        assert_eq!(Histogram::new().quantile(50.0), 0.0);
    }

    #[test]
    fn local_histogram_merges() {
        let mut a = LocalHistogram::new();
        let mut b = LocalHistogram::new();
        for _ in 0..50 {
            a.record(1.0);
            b.record(100.0);
        }
        a.merge(&b);
        assert_eq!(a.count(), 100);
        assert!((a.sum() - 50.0 - 5000.0).abs() < 1e-9);
        let ratio = (STEP_LOG2).exp2();
        let p25 = a.quantile(25.0);
        assert!(p25 >= 1.0 / ratio && p25 <= 1.0 * ratio, "p25={p25}");
        let p75 = a.quantile(75.0);
        assert!(p75 >= 100.0 / ratio && p75 <= 100.0 * ratio, "p75={p75}");
    }

    #[test]
    fn atomic_and_local_quantiles_agree() {
        let h = Histogram::new();
        let mut l = LocalHistogram::new();
        let mut x = 0.37f64;
        for _ in 0..500 {
            // Deterministic pseudo-values spread over several decades.
            x = (x * 97.0) % 1000.0 + 0.01;
            h.record(x);
            l.record(x);
        }
        for q in [0.0, 10.0, 50.0, 90.0, 99.0, 100.0] {
            assert_eq!(h.quantile(q), l.quantile(q), "q={q}");
        }
    }

    #[test]
    fn concurrent_histogram_records_and_renders() {
        // Writers hammer `record` while a reader renders mid-flight;
        // part of the Miri subset (tightened iteration count there) so
        // the interpreter checks the wait-free path's memory accesses.
        let iters: usize = if cfg!(miri) { 40 } else { 4000 };
        let h = std::sync::Arc::new(Histogram::new());
        let writers: Vec<_> = (0..3)
            .map(|w| {
                let h = std::sync::Arc::clone(&h);
                std::thread::spawn(move || {
                    for i in 0..iters {
                        h.record(((w * iters + i) % 700) as f64 + 0.5);
                    }
                })
            })
            .collect();
        // Interleaved reads must render a coherent (monotone) snapshot
        // even while writers are mid-record.
        for _ in 0..4 {
            let text = {
                let mut out = String::new();
                h.render_into("robus_stress", &mut out);
                out
            };
            let mut last = 0u64;
            for line in text.lines().filter(|l| l.starts_with("robus_stress_bucket")) {
                let v: u64 = line.rsplit(' ').next().unwrap().parse().unwrap();
                assert!(v >= last, "non-monotone mid-flight snapshot: {text}");
                last = v;
            }
        }
        for w in writers {
            w.join().unwrap();
        }
        assert_eq!(h.count(), (3 * iters) as u64);
        assert!(h.quantile(0.0) > 0.0);
    }

    #[test]
    fn prometheus_exposition_shape() {
        let m = Metrics::new();
        m.queries_admitted.add(7);
        m.queue_depth.set(3);
        m.solve_ms.record(5.0);
        m.solve_ms.record(50.0);
        let text = m.render_prometheus();
        assert!(text.contains("# TYPE robus_queries_admitted_total counter"));
        assert!(text.contains("robus_queries_admitted_total 7"));
        assert!(text.contains("robus_queue_depth 3"));
        assert!(text.contains("# TYPE robus_solve_ms histogram"));
        assert!(text.contains("robus_solve_ms_bucket{le=\"+Inf\"} 2"));
        assert!(text.contains("robus_solve_ms_count 2"));
        // Cumulative le-series is monotone non-decreasing.
        let mut last = 0u64;
        for line in text.lines().filter(|l| l.starts_with("robus_solve_ms_bucket")) {
            let v: u64 = line.rsplit(' ').next().unwrap().parse().unwrap();
            assert!(v >= last, "non-monotone cumulative buckets: {text}");
            last = v;
        }
    }
}
