//! Cacheable candidate views (§2): anything that can be materialized in
//! the in-memory cache for a performance benefit. ROBUS's default
//! candidate generation uses the base tables a query accesses; the Sales
//! workload additionally uses vertical projections of input tables
//! (§5.1), which is the pluggable candidate-selection hook the paper
//! exercises.

use crate::domain::dataset::DatasetId;

/// Index of a view within its catalog.
#[derive(Debug, Clone, Copy, PartialEq, Eq, PartialOrd, Ord, Hash)]
pub struct ViewId(pub usize);

/// What kind of materialization a view is.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum ViewKind {
    /// The base table/dataset itself, loaded into cache.
    BaseTable,
    /// A vertical projection onto frequently accessed columns.
    VerticalProjection,
}

/// One candidate view.
#[derive(Debug, Clone)]
pub struct View {
    pub id: ViewId,
    pub name: String,
    /// Source dataset this view materializes (projections have one).
    pub dataset: DatasetId,
    pub kind: ViewKind,
    /// Bytes occupied when loaded into the cache.
    pub cached_bytes: u64,
    /// Bytes of disk reading this view saves per query scan that uses it.
    /// For base tables this equals the dataset's disk size; for a
    /// projection it is the disk bytes of the projected columns.
    pub scan_bytes: u64,
}

/// Ordered collection of candidate views.
#[derive(Debug, Clone, Default)]
pub struct ViewCatalog {
    views: Vec<View>,
}

impl ViewCatalog {
    pub fn new() -> Self {
        Self::default()
    }

    pub fn add(
        &mut self,
        name: &str,
        dataset: DatasetId,
        kind: ViewKind,
        cached_bytes: u64,
        scan_bytes: u64,
    ) -> ViewId {
        let id = ViewId(self.views.len());
        self.views.push(View {
            id,
            name: name.to_string(),
            dataset,
            kind,
            cached_bytes,
            scan_bytes,
        });
        id
    }

    pub fn get(&self, id: ViewId) -> &View {
        &self.views[id.0]
    }

    pub fn by_name(&self, name: &str) -> Option<&View> {
        self.views.iter().find(|v| v.name == name)
    }

    /// The view materializing a given dataset, if any.
    pub fn for_dataset(&self, d: DatasetId) -> Option<&View> {
        self.views.iter().find(|v| v.dataset == d)
    }

    pub fn len(&self) -> usize {
        self.views.len()
    }

    pub fn is_empty(&self) -> bool {
        self.views.is_empty()
    }

    pub fn iter(&self) -> impl Iterator<Item = &View> {
        self.views.iter()
    }

    /// Cached sizes as f64s indexed by ViewId — the `view_sizes` input of
    /// the WELFARE knapsack and the L1 utility kernel.
    pub fn cached_sizes(&self) -> Vec<f64> {
        self.views.iter().map(|v| v.cached_bytes as f64).collect()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::domain::dataset::{DatasetCatalog, GB, MB};

    #[test]
    fn catalog_and_lookup() {
        let mut ds = DatasetCatalog::new();
        let d0 = ds.add("sales_0", 20 * GB);
        let d1 = ds.add("sales_1", 10 * GB);
        let mut vc = ViewCatalog::new();
        let v0 = vc.add("sales_0_proj", d0, ViewKind::VerticalProjection, 800 * MB, 2 * GB);
        let v1 = vc.add("sales_1_base", d1, ViewKind::BaseTable, 10 * GB, 10 * GB);
        assert_eq!(vc.len(), 2);
        assert_eq!(vc.get(v0).kind, ViewKind::VerticalProjection);
        assert_eq!(vc.for_dataset(d1).unwrap().id, v1);
        assert_eq!(vc.by_name("sales_0_proj").unwrap().cached_bytes, 800 * MB);
        let sizes = vc.cached_sizes();
        assert_eq!(sizes.len(), 2);
        assert_eq!(sizes[0], (800 * MB) as f64);
    }
}
