//! Tenants: each tenant submits queries to a designated queue with a
//! weight indicating its fair share of system resources (§2). Weights
//! enter the fairness definitions per §3.4 (weighted core) and the
//! fairness index per Equation 5.

/// Index of a tenant.
#[derive(Debug, Clone, Copy, PartialEq, Eq, PartialOrd, Ord, Hash)]
pub struct TenantId(pub usize);

/// One tenant (queue).
#[derive(Debug, Clone)]
pub struct Tenant {
    pub id: TenantId,
    pub name: String,
    /// Fair-share weight λ_i (> 0); equal weights are the common case.
    pub weight: f64,
}

/// The fixed set of tenants for a run.
#[derive(Debug, Clone, Default)]
pub struct TenantSet {
    tenants: Vec<Tenant>,
}

impl TenantSet {
    pub fn new() -> Self {
        Self::default()
    }

    /// N equally weighted tenants named tenant-0..N-1.
    pub fn equal(n: usize) -> Self {
        let mut s = Self::new();
        for i in 0..n {
            s.add(&format!("tenant-{i}"), 1.0);
        }
        s
    }

    pub fn add(&mut self, name: &str, weight: f64) -> TenantId {
        assert!(weight > 0.0, "tenant weight must be positive");
        let id = TenantId(self.tenants.len());
        self.tenants.push(Tenant {
            id,
            name: name.to_string(),
            weight,
        });
        id
    }

    pub fn get(&self, id: TenantId) -> &Tenant {
        &self.tenants[id.0]
    }

    pub fn len(&self) -> usize {
        self.tenants.len()
    }

    pub fn is_empty(&self) -> bool {
        self.tenants.is_empty()
    }

    pub fn iter(&self) -> impl Iterator<Item = &Tenant> {
        self.tenants.iter()
    }

    pub fn weights(&self) -> Vec<f64> {
        self.tenants.iter().map(|t| t.weight).collect()
    }

    pub fn total_weight(&self) -> f64 {
        self.tenants.iter().map(|t| t.weight).sum()
    }

    /// Tenant i's entitled share λ_i / Σλ (the rate endowment of §3.3 in
    /// the weighted extension of §3.4).
    pub fn share(&self, id: TenantId) -> f64 {
        self.get(id).weight / self.total_weight()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn equal_tenants() {
        let ts = TenantSet::equal(4);
        assert_eq!(ts.len(), 4);
        assert_eq!(ts.total_weight(), 4.0);
        assert!((ts.share(TenantId(2)) - 0.25).abs() < 1e-12);
    }

    #[test]
    fn weighted_shares() {
        // §1 Scenario 3: Analyst/Engineer/VP at 1:1:1.5.
        let mut ts = TenantSet::new();
        ts.add("Analyst", 1.0);
        ts.add("Engineer", 1.0);
        let vp = ts.add("VP", 1.5);
        assert!((ts.share(vp) - 1.5 / 3.5).abs() < 1e-12);
    }

    #[test]
    #[should_panic]
    fn zero_weight_rejected() {
        TenantSet::new().add("bad", 0.0);
    }
}
