//! The tenant-utility estimation model (§2, §5.1): a query's utility
//! under a cache configuration is its disk-I/O savings — the bytes it
//! reads — iff *all* datasets it needs are cached, else zero (the
//! all-or-nothing observation of PACMan, paper ref 9). Tenant utility is the sum
//! over the tenant's queries in the batch; U_i* is the best utility the
//! tenant could get with the whole cache to itself (Definition of scaled
//! utility, §3.1).
//!
//! [`BatchUtilities`] is the *batch problem*: everything a view-selection
//! policy needs — candidate view sizes, the cache budget, aggregated
//! per-tenant query classes, and U_i*. Configurations are [`ConfigMask`]
//! bitsets; a precomputed [`BatchIndex`] stores each query class's
//! required-view bitmask (grouped by tenant) plus 1/U_i*, so evaluating
//! U_i(S)/V_i(S) is a word-wise subset test per class instead of a
//! per-view index walk. The reusable [`WelfareTemplate`] turns the
//! WELFARE oracle (Definition 5) into a value-rewrite + solve, so the
//! multiplicative-weights loops stop rebuilding the instance every
//! iteration.

use crate::cache::tier::TierAssignment;
use crate::domain::query::Query;
use crate::domain::tenant::TenantSet;
use crate::domain::view::ViewCatalog;
use crate::solver::knapsack::{ValuedQuery, WelfareProblem, WelfareSolution};
use crate::util::mask::ConfigMask;

/// Tier dimension of the batch problem (two-tier mode only): the SSD
/// byte budget and the utility discount an SSD-resident view earns
/// ([`crate::cache::tier::TierCostModel::ssd_discount`]). `None` on
/// [`BatchUtilities::tier`] selects the legacy single-tier problem,
/// whose evaluation paths stay bit-identical.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct TierPlan {
    /// SSD tier capacity in bytes (same unit as `budget`).
    pub ssd_budget: f64,
    /// Fraction of a class's utility retained when its views are
    /// resident but not all in RAM, in [0, 1].
    pub discount: f64,
}

/// Utility model configuration.
#[derive(Debug, Clone)]
pub struct UtilityModel {
    /// Multiplier applied to the estimated benefit of views already in
    /// cache (stateful mode, §5.4; γ > 1 biases toward keeping them).
    pub stateful_gamma: f64,
}

impl Default for UtilityModel {
    fn default() -> Self {
        Self { stateful_gamma: 1.0 }
    }
}

/// One aggregated query class: all queries of `tenant` requiring exactly
/// the same view set, with summed utility.
#[derive(Debug, Clone)]
pub struct QueryClass {
    pub tenant: usize,
    /// Sorted required view indices.
    pub views: Vec<usize>,
    /// Summed I/O-savings utility (bytes) of the class.
    pub utility: f64,
    /// Number of query instances aggregated.
    pub count: usize,
}

/// Precomputed evaluation index over the batch's query classes — the
/// word-wise fast path behind `utilities()`/`scaled_utilities()` and the
/// restricted WELFARE evaluations.
#[derive(Debug, Clone, Default)]
pub struct BatchIndex {
    /// Required-view bitmask per class, same order as
    /// [`BatchUtilities::classes`] (which is sorted by tenant).
    pub class_masks: Vec<ConfigMask>,
    /// `tenant_ranges[i]` = half-open class range `[start, end)` of
    /// tenant `i` within `classes`/`class_masks`.
    pub tenant_ranges: Vec<(u32, u32)>,
    /// Precomputed 1/U_i* per tenant; 0.0 flags an inactive tenant
    /// (no queries in the batch).
    pub inv_u_star: Vec<f64>,
}

impl BatchIndex {
    fn build(n_tenants: usize, n_views: usize, classes: &[QueryClass], u_star: &[f64]) -> Self {
        let class_masks = classes
            .iter()
            .map(|c| ConfigMask::from_indices(n_views, &c.views))
            .collect();
        // Classes are sorted by tenant (BTreeMap key order in `build`),
        // so each tenant's classes form one contiguous run.
        let mut tenant_ranges = vec![(0u32, 0u32); n_tenants];
        let mut start = 0usize;
        for (t, range) in tenant_ranges.iter_mut().enumerate() {
            let mut end = start;
            while end < classes.len() && classes[end].tenant == t {
                end += 1;
            }
            *range = (start as u32, end as u32);
            start = end;
        }
        debug_assert_eq!(start, classes.len(), "classes not sorted by tenant");
        let inv_u_star = u_star
            .iter()
            .map(|&u| if u > 0.0 { 1.0 / u } else { 0.0 })
            .collect();
        Self {
            class_masks,
            tenant_ranges,
            inv_u_star,
        }
    }
}

/// The per-batch allocation problem.
#[derive(Debug, Clone)]
pub struct BatchUtilities {
    pub n_tenants: usize,
    /// Tenant weights λ_i.
    pub weights: Vec<f64>,
    /// Cached size of each candidate view.
    pub view_sizes: Vec<f64>,
    /// Cache budget.
    pub budget: f64,
    /// Aggregated query classes, sorted by tenant.
    pub classes: Vec<QueryClass>,
    /// U_i*: best achievable utility per tenant alone in the system
    /// (0.0 for tenants with no queries in the batch).
    pub u_star: Vec<f64>,
    /// Precomputed bitmask index over `classes`.
    pub index: BatchIndex,
    /// Two-tier extension (`None` = legacy single-tier problem; every
    /// evaluation path then avoids tier arithmetic entirely).
    pub tier: Option<TierPlan>,
}

impl BatchUtilities {
    /// Build the batch problem from raw queries. `boost` is an optional
    /// per-view multiplier vector (stateful cache boost; `None` for the
    /// stateless default).
    pub fn build(
        tenants: &TenantSet,
        views: &ViewCatalog,
        budget: f64,
        queries: &[Query],
        boost: Option<&[f64]>,
    ) -> Self {
        let n_tenants = tenants.len();
        let view_sizes = views.cached_sizes();

        // Aggregate queries into classes keyed by (tenant, view set).
        use std::collections::BTreeMap;
        let mut agg: BTreeMap<(usize, Vec<usize>), (f64, usize)> = BTreeMap::new();
        for q in queries {
            let mut vs: Vec<usize> = q.required_views.iter().map(|v| v.0).collect();
            vs.sort_unstable();
            vs.dedup();
            // A query's utility can be boosted per-view (stateful mode):
            // apply the mean boost of its views to its I/O savings.
            let base = q.bytes_read as f64;
            let util = match boost {
                None => base,
                Some(b) => {
                    let m = vs.iter().map(|&v| b[v]).sum::<f64>() / vs.len().max(1) as f64;
                    base * m
                }
            };
            let e = agg.entry((q.tenant.0, vs)).or_insert((0.0, 0));
            e.0 += util;
            e.1 += 1;
        }
        let classes: Vec<QueryClass> = agg
            .into_iter()
            .map(|((tenant, views), (utility, count))| QueryClass {
                tenant,
                views,
                utility,
                count,
            })
            .collect();

        let mut this = Self {
            n_tenants,
            weights: tenants.weights(),
            view_sizes,
            budget,
            classes,
            u_star: vec![0.0; n_tenants],
            index: BatchIndex::default(),
            tier: None,
        };
        this.u_star = (0..n_tenants).map(|i| this.solo_optimum(i).value).collect();
        this.index = BatchIndex::build(
            n_tenants,
            this.view_sizes.len(),
            &this.classes,
            &this.u_star,
        );
        this
    }

    /// Tenants that submitted at least one query this batch.
    pub fn active_tenants(&self) -> Vec<usize> {
        (0..self.n_tenants)
            .filter(|&i| self.u_star[i] > 0.0)
            .collect()
    }

    /// U_i(S): tenant i's utility under configuration `selected` —
    /// word-wise subset tests over the tenant's own class range.
    pub fn tenant_utility(&self, tenant: usize, selected: &ConfigMask) -> f64 {
        let (lo, hi) = self.index.tenant_ranges[tenant];
        let (lo, hi) = (lo as usize, hi as usize);
        self.classes[lo..hi]
            .iter()
            .zip(&self.index.class_masks[lo..hi])
            .filter(|(_, m)| selected.contains_all(m))
            .map(|(c, _)| c.utility)
            .sum()
    }

    /// U(S) for all tenants.
    pub fn utilities(&self, selected: &ConfigMask) -> Vec<f64> {
        let mut u = vec![0.0; self.n_tenants];
        for (c, m) in self.classes.iter().zip(&self.index.class_masks) {
            if selected.contains_all(m) {
                u[c.tenant] += c.utility;
            }
        }
        u
    }

    /// Raw U over a `(view, tier)` assignment: a class counts fully
    /// when its views are all in RAM, at the tier discount when they
    /// are all resident (RAM ∪ SSD) but not all in RAM, and zero
    /// otherwise. With an empty SSD plane this delegates to
    /// [`BatchUtilities::utilities`] — bit-identical to the single-tier
    /// path by construction.
    pub fn utilities_pair(&self, tiers: &TierAssignment) -> Vec<f64> {
        if tiers.ssd.none_set() {
            return self.utilities(&tiers.ram);
        }
        let discount = self.tier.map(|t| t.discount).unwrap_or(0.0);
        let union = tiers.union();
        let mut u = vec![0.0; self.n_tenants];
        for (c, m) in self.classes.iter().zip(&self.index.class_masks) {
            if tiers.ram.contains_all(m) {
                u[c.tenant] += c.utility;
            } else if union.contains_all(m) {
                u[c.tenant] += c.utility * discount;
            }
        }
        u
    }

    /// V_i(S) = U_i(S)/U_i* for all tenants (1.0 for inactive tenants —
    /// a tenant with no queries is trivially fully satisfied).
    pub fn scaled_utilities(&self, selected: &ConfigMask) -> Vec<f64> {
        let mut v = self.utilities(selected);
        for (i, vi) in v.iter_mut().enumerate() {
            // Division (not multiplication by inv_u_star) keeps results
            // bit-identical to the legacy per-view evaluation path; the
            // reciprocal serves as the activity flag and feeds the
            // accelerated marshalling paths.
            *vi = if self.index.inv_u_star[i] > 0.0 {
                *vi / self.u_star[i]
            } else {
                1.0
            };
        }
        v
    }

    /// Attach (or clear) the tier dimension. Builder-style so callers
    /// can keep the single `build(..)` construction site.
    pub fn with_tier(mut self, tier: Option<TierPlan>) -> Self {
        self.tier = tier;
        self
    }

    /// V_i over a `(view, tier)` assignment: a class counts fully when
    /// its views are all in RAM, at the tier discount when they are all
    /// resident (RAM ∪ SSD) but not all in RAM, and zero otherwise.
    ///
    /// With an empty SSD plane this delegates to
    /// [`BatchUtilities::scaled_utilities`] — bit-identical to the
    /// single-tier path by construction.
    pub fn scaled_utilities_pair(&self, tiers: &TierAssignment) -> Vec<f64> {
        if tiers.ssd.none_set() {
            return self.scaled_utilities(&tiers.ram);
        }
        let discount = self.tier.map(|t| t.discount).unwrap_or(0.0);
        let union = tiers.union();
        let mut v = vec![0.0; self.n_tenants];
        for (c, m) in self.classes.iter().zip(&self.index.class_masks) {
            if tiers.ram.contains_all(m) {
                v[c.tenant] += c.utility;
            } else if union.contains_all(m) {
                v[c.tenant] += c.utility * discount;
            }
        }
        for (i, vi) in v.iter_mut().enumerate() {
            *vi = if self.index.inv_u_star[i] > 0.0 {
                *vi / self.u_star[i]
            } else {
                1.0
            };
        }
        v
    }

    /// Whether a `(view, tier)` assignment fits both tier budgets.
    pub fn tier_feasible(&self, tiers: &TierAssignment) -> bool {
        let ssd_budget = self.tier.map(|t| t.ssd_budget).unwrap_or(0.0);
        self.size_of(&tiers.ram) <= self.budget + 1e-9
            && self.size_of(&tiers.ssd) <= ssd_budget + 1e-9
    }

    /// Total cached size of a configuration.
    pub fn size_of(&self, selected: &ConfigMask) -> f64 {
        selected.ones().map(|v| self.view_sizes[v]).sum()
    }

    pub fn n_views(&self) -> usize {
        self.view_sizes.len()
    }

    /// The single-tenant optimum configuration (defines U_i*).
    pub fn solo_optimum(&self, tenant: usize) -> WelfareSolution {
        let queries: Vec<ValuedQuery> = self
            .classes
            .iter()
            .filter(|c| c.tenant == tenant)
            .map(|c| ValuedQuery {
                value: c.utility,
                views: c.views.clone(),
            })
            .collect();
        WelfareProblem {
            view_sizes: self.view_sizes.clone(),
            budget: self.budget,
            queries,
        }
        .solve_exact()
    }

    /// WELFARE(w) instance (Definition 5): maximize Σ_i w_i·V_i(S) —
    /// each query class contributes w_t · utility / U_t* when satisfied.
    ///
    /// For repeated solves with fresh weights (the MW hot loops), use
    /// [`BatchUtilities::welfare_template`] instead — it builds the
    /// skeleton once.
    pub fn welfare_problem(&self, w: &[f64]) -> WelfareProblem {
        assert_eq!(w.len(), self.n_tenants);
        let queries: Vec<ValuedQuery> = self
            .classes
            .iter()
            .filter(|c| self.u_star[c.tenant] > 0.0)
            .map(|c| ValuedQuery {
                value: w[c.tenant] * c.utility / self.u_star[c.tenant],
                views: c.views.clone(),
            })
            .collect();
        WelfareProblem {
            view_sizes: self.view_sizes.clone(),
            budget: self.budget,
            queries,
        }
    }

    /// Reusable WELFARE(w) instance: clone the class skeleton once, then
    /// [`WelfareTemplate::solve`] only rewrites the per-class values for
    /// each new dual-weight vector. Produces solutions identical to
    /// `welfare_problem(w).solve_exact()`.
    pub fn welfare_template(&self) -> WelfareTemplate {
        let mut queries = Vec::new();
        let mut terms = Vec::new();
        for c in &self.classes {
            if self.u_star[c.tenant] > 0.0 {
                queries.push(ValuedQuery {
                    value: 0.0,
                    views: c.views.clone(),
                });
                terms.push((c.tenant, c.utility, self.u_star[c.tenant]));
            }
        }
        WelfareTemplate {
            problem: WelfareProblem {
                view_sizes: self.view_sizes.clone(),
                budget: self.budget,
                queries,
            },
            terms,
            tier: self.tier,
        }
    }

    /// Total (unscaled, unweighted) utility — OPTP's objective.
    pub fn total_utility_problem(&self) -> WelfareProblem {
        let queries: Vec<ValuedQuery> = self
            .classes
            .iter()
            .map(|c| ValuedQuery {
                value: c.utility,
                views: c.views.clone(),
            })
            .collect();
        WelfareProblem {
            view_sizes: self.view_sizes.clone(),
            budget: self.budget,
            queries,
        }
    }
}

/// A prebuilt WELFARE(w) skeleton (see
/// [`BatchUtilities::welfare_template`]): per-class view sets and sizes
/// are fixed; each `solve` call rewrites only the values
/// `w_t · utility / U_t*` before running the exact oracle.
#[derive(Debug, Clone)]
pub struct WelfareTemplate {
    problem: WelfareProblem,
    /// `(tenant, utility, u_star)` per query class in `problem.queries`
    /// (active-tenant classes only).
    terms: Vec<(usize, f64, f64)>,
    /// Tier dimension inherited from the batch problem (`None` =
    /// single-tier; `solve_pair` then never runs its second phase).
    tier: Option<TierPlan>,
}

impl WelfareTemplate {
    /// Solve WELFARE(w) for dual weights `w` (length = n_tenants).
    pub fn solve(&mut self, w: &[f64]) -> WelfareSolution {
        for (q, &(t, util, u_star)) in self.problem.queries.iter_mut().zip(&self.terms) {
            q.value = w[t] * util / u_star;
        }
        self.problem.solve_exact()
    }

    /// Tiered WELFARE(w): phase 1 is the unchanged exact RAM solve
    /// (same float operations as [`WelfareTemplate::solve`]); phase 2 —
    /// skipped entirely in single-tier mode — runs a second knapsack
    /// over the SSD budget for the classes RAM left unsatisfied, with
    /// RAM-resident views free (size 0, since union residency is what
    /// satisfies a class) and values scaled by the tier discount.
    pub fn solve_pair(&mut self, w: &[f64]) -> TierAssignment {
        let ram_sol = self.solve(w);
        let ram = ConfigMask::from_bools(&ram_sol.selected);
        let Some(plan) = self.tier else {
            return TierAssignment::single(ram);
        };
        if plan.ssd_budget <= 0.0 || plan.discount <= 0.0 {
            return TierAssignment::single(ram);
        }
        let mut sizes = self.problem.view_sizes.clone();
        for v in ram.ones() {
            sizes[v] = 0.0;
        }
        let queries: Vec<ValuedQuery> = self
            .problem
            .queries
            .iter()
            .filter(|q| !q.views.iter().all(|&v| ram.get(v)))
            .map(|q| ValuedQuery {
                value: q.value * plan.discount,
                views: q.views.clone(),
            })
            .collect();
        let ssd_sol = WelfareProblem {
            view_sizes: sizes,
            budget: plan.ssd_budget,
            queries,
        }
        .solve_exact();
        let mut ssd = ConfigMask::from_bools(&ssd_sol.selected);
        // RAM-resident views may be "selected" in phase 2 (they are
        // free); drop them to keep the planes disjoint.
        for v in ram.ones() {
            ssd.set(v, false);
        }
        TierAssignment { ram, ssd }
    }

    /// The underlying (last-weighted) problem, e.g. for budget overrides.
    pub fn problem(&self) -> &WelfareProblem {
        &self.problem
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::domain::dataset::DatasetCatalog;
    use crate::domain::query::{Query, QueryId};
    use crate::domain::tenant::{TenantId, TenantSet};
    use crate::domain::view::{ViewCatalog, ViewId, ViewKind};

    fn mask(bits: &[bool]) -> ConfigMask {
        ConfigMask::from_bools(bits)
    }

    /// The SpaceBook instance of Table 1: views R,S,P of unit size M,
    /// cache M; Analyst/Engineer utilities 2,1,0 and VP 0,1,2.
    pub fn spacebook() -> (TenantSet, ViewCatalog, Vec<Query>) {
        let mut ds = DatasetCatalog::new();
        let mut vc = ViewCatalog::new();
        for name in ["R", "S", "P"] {
            let d = ds.add(name, 100);
            vc.add(name, d, ViewKind::BaseTable, 100, 100);
        }
        let mut ts = TenantSet::new();
        let analyst = ts.add("Analyst", 1.0);
        let engineer = ts.add("Engineer", 1.0);
        let vp = ts.add("VP", 1.0);
        let mut queries = Vec::new();
        let mut qid = 0u64;
        let mut push = |t: TenantId, v: usize, util: u64, queries: &mut Vec<Query>| {
            queries.push(Query {
                id: QueryId({ qid += 1; qid }),
                tenant: t,
                arrival: 0.0,
                template: "spacebook".into(),
                required_views: vec![ViewId(v)],
                bytes_read: util,
                compute_cost: 0.0,
            });
        };
        // Utilities per Table 1 (2 units = two queries of 1 byte... use
        // bytes directly as utility units).
        push(analyst, 0, 2, &mut queries);
        push(analyst, 1, 1, &mut queries);
        push(engineer, 0, 2, &mut queries);
        push(engineer, 1, 1, &mut queries);
        push(vp, 1, 1, &mut queries);
        push(vp, 2, 2, &mut queries);
        (ts, vc, queries)
    }

    #[test]
    fn spacebook_u_star_and_utilities() {
        let (ts, vc, queries) = spacebook();
        let b = BatchUtilities::build(&ts, &vc, 100.0, &queries, None);
        // Alone with cache M each tenant caches its best single view.
        assert_eq!(b.u_star, vec![2.0, 2.0, 2.0]);
        // Config {R}: utilities (2,2,0); scaled (1,1,0).
        let s_r = mask(&[true, false, false]);
        assert_eq!(b.utilities(&s_r), vec![2.0, 2.0, 0.0]);
        assert_eq!(b.scaled_utilities(&s_r), vec![1.0, 1.0, 0.0]);
        // Config {S}: everyone gets 1 → scaled 0.5.
        let s_s = mask(&[false, true, false]);
        assert_eq!(b.scaled_utilities(&s_s), vec![0.5, 0.5, 0.5]);
        assert_eq!(b.active_tenants(), vec![0, 1, 2]);
    }

    #[test]
    fn batch_index_groups_classes_by_tenant() {
        let (ts, vc, queries) = spacebook();
        let b = BatchUtilities::build(&ts, &vc, 100.0, &queries, None);
        assert_eq!(b.index.class_masks.len(), b.classes.len());
        for (t, &(lo, hi)) in b.index.tenant_ranges.iter().enumerate() {
            for c in &b.classes[lo as usize..hi as usize] {
                assert_eq!(c.tenant, t);
            }
        }
        let total: u32 = b
            .index
            .tenant_ranges
            .iter()
            .map(|&(lo, hi)| hi - lo)
            .sum();
        assert_eq!(total as usize, b.classes.len());
        // Each class mask matches its sorted view list.
        for (c, m) in b.classes.iter().zip(&b.index.class_masks) {
            assert_eq!(m.ones().collect::<Vec<_>>(), c.views);
        }
        // inv_u_star is the reciprocal for active tenants.
        for (i, &inv) in b.index.inv_u_star.iter().enumerate() {
            assert!((inv - 1.0 / b.u_star[i]).abs() < 1e-15);
        }
    }

    #[test]
    fn welfare_with_uniform_weights_picks_r() {
        let (ts, vc, queries) = spacebook();
        let b = BatchUtilities::build(&ts, &vc, 100.0, &queries, None);
        // Equal weights: scaled welfare of {R} = 2, {S} = 1.5, {P} = 1.
        let w = vec![1.0, 1.0, 1.0];
        let sol = b.welfare_problem(&w).solve_exact();
        assert_eq!(sol.selected, vec![true, false, false]);
        assert!((sol.value - 2.0).abs() < 1e-9);
    }

    #[test]
    fn welfare_weights_steer_selection() {
        let (ts, vc, queries) = spacebook();
        let b = BatchUtilities::build(&ts, &vc, 100.0, &queries, None);
        // Heavy weight on VP: {P} wins (value 5·(2/2) = 5 > others).
        let sol = b.welfare_problem(&[0.1, 0.1, 5.0]).solve_exact();
        assert_eq!(sol.selected, vec![false, false, true]);
    }

    #[test]
    fn welfare_template_matches_problem_exactly() {
        let (ts, vc, queries) = spacebook();
        let b = BatchUtilities::build(&ts, &vc, 100.0, &queries, None);
        let mut template = b.welfare_template();
        for w in [
            vec![1.0, 1.0, 1.0],
            vec![0.1, 0.1, 5.0],
            vec![0.0, 1.0, 0.0],
            vec![2.5, 0.25, 0.75],
        ] {
            let via_template = template.solve(&w);
            let via_problem = b.welfare_problem(&w).solve_exact();
            assert_eq!(via_template.selected, via_problem.selected, "w={w:?}");
            assert_eq!(via_template.value, via_problem.value, "w={w:?}");
        }
    }

    #[test]
    fn scenario4_doubled_cache_weighted() {
        // §1 Scenario 4: weights 1:1:1.5, cache 2M → utility-max caches
        // {R,S} (weighted raw utility 7.5).
        let (mut ts, vc, queries) = spacebook();
        ts = {
            let mut t = TenantSet::new();
            t.add("Analyst", 1.0);
            t.add("Engineer", 1.0);
            t.add("VP", 1.5);
            t
        };
        let b = BatchUtilities::build(&ts, &vc, 200.0, &queries, None);
        // Raw weighted utility-max (not scaled): emulate via welfare with
        // weights w_i = λ_i · U_i* (undo the 1/U* scaling).
        let w: Vec<f64> = b
            .weights
            .iter()
            .zip(&b.u_star)
            .map(|(l, u)| l * u)
            .collect();
        let sol = b.welfare_problem(&w).solve_exact();
        assert_eq!(sol.selected, vec![true, true, false]);
    }

    #[test]
    fn inactive_tenant_masked() {
        let (ts, vc, mut queries) = spacebook();
        queries.retain(|q| q.tenant.0 != 2); // VP submits nothing
        let b = BatchUtilities::build(&ts, &vc, 100.0, &queries, None);
        assert_eq!(b.u_star[2], 0.0);
        assert_eq!(b.index.inv_u_star[2], 0.0);
        assert_eq!(b.active_tenants(), vec![0, 1]);
        // Scaled utility of inactive tenant reported as 1.0 (satisfied).
        assert_eq!(b.scaled_utilities(&mask(&[true, false, false]))[2], 1.0);
        // Welfare problem ignores the inactive tenant regardless of w.
        let p = b.welfare_problem(&[1.0, 1.0, 100.0]);
        assert!(p.queries.iter().all(|q| q.value.is_finite()));
    }

    #[test]
    fn class_aggregation_merges_duplicates() {
        let (ts, vc, mut queries) = spacebook();
        let extra = queries[0].clone();
        queries.push(Query {
            id: QueryId(99),
            ..extra
        });
        let b = BatchUtilities::build(&ts, &vc, 100.0, &queries, None);
        let class = b
            .classes
            .iter()
            .find(|c| c.tenant == 0 && c.views == vec![0])
            .unwrap();
        assert_eq!(class.count, 2);
        assert_eq!(class.utility, 4.0);
    }

    #[test]
    fn stateful_boost_raises_cached_view_value() {
        let (ts, vc, queries) = spacebook();
        let boost = vec![2.0, 1.0, 1.0]; // view R already cached, γ=2
        let b = BatchUtilities::build(&ts, &vc, 100.0, &queries, Some(&boost));
        let plain = BatchUtilities::build(&ts, &vc, 100.0, &queries, None);
        let r_only = mask(&[true, false, false]);
        let s_only = mask(&[false, true, false]);
        assert!(b.tenant_utility(0, &r_only) > plain.tenant_utility(0, &r_only));
        assert_eq!(b.tenant_utility(0, &s_only), plain.tenant_utility(0, &s_only));
    }

    #[test]
    fn size_of_sums_selected_views() {
        let (ts, vc, queries) = spacebook();
        let b = BatchUtilities::build(&ts, &vc, 100.0, &queries, None);
        assert_eq!(b.size_of(&mask(&[true, false, true])), 200.0);
        assert_eq!(b.size_of(&ConfigMask::empty(3)), 0.0);
    }

    #[test]
    fn solve_pair_without_tier_is_single_plane() {
        let (ts, vc, queries) = spacebook();
        let b = BatchUtilities::build(&ts, &vc, 100.0, &queries, None);
        let mut t = b.welfare_template();
        let w = vec![1.0, 1.0, 1.0];
        let pair = t.solve_pair(&w);
        assert!(pair.ssd.none_set());
        let sol = b.welfare_problem(&w).solve_exact();
        assert_eq!(pair.ram, ConfigMask::from_bools(&sol.selected));
    }

    #[test]
    fn solve_pair_fills_ssd_with_next_best_views() {
        let (ts, vc, queries) = spacebook();
        let plan = TierPlan {
            ssd_budget: 100.0,
            discount: 0.5,
        };
        let b = BatchUtilities::build(&ts, &vc, 100.0, &queries, None).with_tier(Some(plan));
        let mut t = b.welfare_template();
        let pair = t.solve_pair(&[1.0, 1.0, 1.0]);
        // RAM plane is the untouched phase-1 optimum {R}; the SSD plane
        // adds {S}, whose discounted residual welfare (3·0.5/2 = 0.75)
        // beats {P} (1·0.5 = 0.5).
        assert_eq!(pair.ram, mask(&[true, false, false]));
        assert_eq!(pair.ssd, mask(&[false, true, false]));
        assert!(b.tier_feasible(&pair));
    }

    #[test]
    fn scaled_utilities_pair_discounts_ssd_residency() {
        let (ts, vc, queries) = spacebook();
        let plan = TierPlan {
            ssd_budget: 100.0,
            discount: 0.5,
        };
        let b = BatchUtilities::build(&ts, &vc, 100.0, &queries, None).with_tier(Some(plan));
        let tiers = TierAssignment {
            ram: mask(&[true, false, false]),
            ssd: mask(&[false, true, false]),
        };
        // RAM {R} gives (1, 1, 0); SSD {S} adds half of each S class:
        // analyst/engineer +0.5·1/2, VP +0.5·1/2.
        assert_eq!(b.scaled_utilities_pair(&tiers), vec![1.25, 1.25, 0.25]);
        // Empty SSD plane delegates to the single-tier evaluation.
        let single = TierAssignment::single(mask(&[true, false, false]));
        assert_eq!(
            b.scaled_utilities_pair(&single),
            b.scaled_utilities(&single.ram)
        );
    }

    #[test]
    fn tier_feasible_checks_both_planes() {
        let (ts, vc, queries) = spacebook();
        let plan = TierPlan {
            ssd_budget: 100.0,
            discount: 0.5,
        };
        let b = BatchUtilities::build(&ts, &vc, 100.0, &queries, None).with_tier(Some(plan));
        let ok = TierAssignment {
            ram: mask(&[true, false, false]),
            ssd: mask(&[false, true, false]),
        };
        assert!(b.tier_feasible(&ok));
        let ssd_over = TierAssignment {
            ram: mask(&[true, false, false]),
            ssd: mask(&[false, true, true]),
        };
        assert!(!b.tier_feasible(&ssd_over));
        // Without a tier plan the SSD budget is zero.
        let b0 = BatchUtilities::build(&ts, &vc, 100.0, &queries, None);
        assert!(!b0.tier_feasible(&ok));
        assert!(b0.tier_feasible(&TierAssignment::single(mask(&[true, false, false]))));
    }
}
