//! TPC-H at scale factor 5 (§5.1's second data category): the 8 base
//! tables with standard size ratios, plus the 15 benchmark query
//! templates used by the evaluation's h₁ workload mix. Candidate views
//! for TPC-H queries are the base tables they access (ROBUS's default
//! candidate generation, §2) — notably every template reads `lineitem`
//! (~3.7 GB at SF 5), which is why STATIC cannot cache anything useful
//! in a 4-way-partitioned 6 GB budget (§5.3.1).

use crate::domain::dataset::{DatasetCatalog, DatasetId, KB, MB};
use crate::domain::view::{ViewCatalog, ViewId, ViewKind};

/// Scale factor used in the paper's evaluation.
pub const SCALE_FACTOR: u64 = 5;

/// TPC-H table flat-file sizes at SF 1, in bytes (standard dbgen output).
const SF1_SIZES: [(&str, u64); 8] = [
    ("lineitem", 759 * MB),
    ("orders", 171 * MB),
    ("partsupp", 118 * MB),
    ("part", 24 * MB),
    ("customer", 24 * MB),
    ("supplier", 1417 * KB),
    ("nation", 2 * KB),
    ("region", 1 * KB),
];

/// A TPC-H query template: name, accessed tables, and a relative compute
/// weight (joins/aggregations beyond the scan; arbitrary units of
/// core-seconds per GB scanned, heavier for many-way joins).
#[derive(Debug, Clone)]
pub struct TpchTemplate {
    pub name: &'static str,
    pub tables: &'static [&'static str],
    pub compute_weight: f64,
}

/// The 15 templates of the h₁ workload (all include `lineitem`).
pub const TEMPLATES: [TpchTemplate; 15] = [
    TpchTemplate { name: "q1", tables: &["lineitem"], compute_weight: 1.0 },
    TpchTemplate { name: "q3", tables: &["customer", "orders", "lineitem"], compute_weight: 1.6 },
    TpchTemplate { name: "q4", tables: &["orders", "lineitem"], compute_weight: 1.3 },
    TpchTemplate { name: "q5", tables: &["customer", "orders", "lineitem", "supplier", "nation", "region"], compute_weight: 2.2 },
    TpchTemplate { name: "q6", tables: &["lineitem"], compute_weight: 0.8 },
    TpchTemplate { name: "q7", tables: &["supplier", "lineitem", "orders", "customer", "nation"], compute_weight: 2.0 },
    TpchTemplate { name: "q8", tables: &["part", "supplier", "lineitem", "orders", "customer", "nation", "region"], compute_weight: 2.4 },
    TpchTemplate { name: "q9", tables: &["part", "supplier", "lineitem", "partsupp", "orders", "nation"], compute_weight: 2.6 },
    TpchTemplate { name: "q10", tables: &["customer", "orders", "lineitem", "nation"], compute_weight: 1.8 },
    TpchTemplate { name: "q12", tables: &["orders", "lineitem"], compute_weight: 1.2 },
    TpchTemplate { name: "q14", tables: &["lineitem", "part"], compute_weight: 1.1 },
    TpchTemplate { name: "q17", tables: &["lineitem", "part"], compute_weight: 1.5 },
    TpchTemplate { name: "q18", tables: &["customer", "orders", "lineitem"], compute_weight: 2.0 },
    TpchTemplate { name: "q19", tables: &["lineitem", "part"], compute_weight: 1.4 },
    TpchTemplate { name: "q21", tables: &["supplier", "lineitem", "orders", "nation"], compute_weight: 2.3 },
];

/// The TPC-H catalog: 8 datasets, one base-table candidate view each.
#[derive(Debug, Clone)]
pub struct TpchCatalog {
    pub datasets: DatasetCatalog,
    pub views: ViewCatalog,
    pub view_of_dataset: Vec<ViewId>,
}

impl TpchCatalog {
    pub fn build() -> Self {
        let mut datasets = DatasetCatalog::new();
        let mut views = ViewCatalog::new();
        let mut view_of_dataset = Vec::new();
        for (name, sf1) in SF1_SIZES {
            let bytes = sf1 * SCALE_FACTOR;
            let d = datasets.add(name, bytes);
            // Base-table views: in-memory footprint ≈ on-disk scan bytes.
            let v = views.add(name, d, ViewKind::BaseTable, bytes, bytes);
            view_of_dataset.push(v);
        }
        Self {
            datasets,
            views,
            view_of_dataset,
        }
    }

    pub fn dataset(&self, name: &str) -> DatasetId {
        self.datasets
            .by_name(name)
            .unwrap_or_else(|| panic!("unknown tpch table {name}"))
            .id
    }

    pub fn view(&self, name: &str) -> ViewId {
        self.views
            .by_name(name)
            .unwrap_or_else(|| panic!("unknown tpch view {name}"))
            .id
    }

    /// Required views + total bytes + compute cost for a template.
    pub fn template_footprint(&self, t: &TpchTemplate) -> (Vec<ViewId>, u64, f64) {
        let views: Vec<ViewId> = t.tables.iter().map(|n| self.view(n)).collect();
        let bytes: u64 = views
            .iter()
            .map(|&v| self.views.get(v).scan_bytes)
            .sum();
        // Join/aggregation compute: ~10 core-seconds per compute-weighted
        // GiB (TPC-H plans are less row-bound than the Sales aggregations).
        let compute = 10.0 * t.compute_weight * (bytes as f64 / (1u64 << 30) as f64);
        (views, bytes, compute)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::domain::dataset::GB;

    #[test]
    fn lineitem_is_about_3_7_gb() {
        let cat = TpchCatalog::build();
        let li = cat.datasets.by_name("lineitem").unwrap();
        let gb = li.disk_bytes as f64 / GB as f64;
        assert!((3.5..4.0).contains(&gb), "lineitem={gb} GB");
    }

    #[test]
    fn every_template_reads_lineitem() {
        for t in &TEMPLATES {
            assert!(t.tables.contains(&"lineitem"), "{} misses lineitem", t.name);
        }
        assert_eq!(TEMPLATES.len(), 15);
    }

    #[test]
    fn template_footprints() {
        let cat = TpchCatalog::build();
        let q1 = &TEMPLATES[0];
        let (views, bytes, compute) = cat.template_footprint(q1);
        assert_eq!(views.len(), 1);
        assert_eq!(bytes, 759 * MB * SCALE_FACTOR);
        assert!(compute > 0.0);
        // q8 reads 7 tables.
        let q8 = TEMPLATES.iter().find(|t| t.name == "q8").unwrap();
        let (views8, bytes8, _) = cat.template_footprint(q8);
        assert_eq!(views8.len(), 7);
        assert!(bytes8 > bytes);
    }

    #[test]
    fn all_template_tables_resolve() {
        let cat = TpchCatalog::build();
        for t in &TEMPLATES {
            for table in t.tables {
                let _ = cat.view(table);
            }
        }
    }
}
