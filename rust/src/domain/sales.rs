//! The synthetic "Sales" catalog of §5.1: 30 datasets matching the
//! TPC-DS sales-table schemas (store_sales / catalog_sales / web_sales),
//! totalling ~600 GB on disk, each with one vertical-projection candidate
//! view over its most frequently accessed columns. Cached view sizes
//! range from 118 MB to 3.6 GB, matching Figure 3's profile.

use crate::domain::dataset::{DatasetCatalog, DatasetId, GB, MB};
use crate::domain::view::{ViewCatalog, ViewId, ViewKind};

/// Number of Sales datasets (per §5.1).
pub const NUM_SALES_DATASETS: usize = 30;
/// Smallest and largest candidate-view cache footprints (Figure 3).
pub const MIN_VIEW_BYTES: u64 = 118 * MB;
pub const MAX_VIEW_BYTES: u64 = 3686 * MB; // 3.6 GB

/// The generated Sales catalog: datasets plus one projection view each.
#[derive(Debug, Clone)]
pub struct SalesCatalog {
    pub datasets: DatasetCatalog,
    pub views: ViewCatalog,
    /// `views[i]` materializes `datasets[i]`.
    pub view_of_dataset: Vec<ViewId>,
}

impl SalesCatalog {
    /// Build the deterministic catalog. View cache sizes are log-spaced
    /// from `MAX_VIEW_BYTES` down to `MIN_VIEW_BYTES` (dataset 0 is the
    /// largest — workload Zipf permutations decide which dataset is
    /// *popular*, so fixing the size order loses no generality). Disk
    /// sizes scale the projections back up so the catalog totals ~600 GB,
    /// mirroring "views on the most frequently accessed columns" being a
    /// small fraction of the raw fact data.
    pub fn build() -> Self {
        let mut datasets = DatasetCatalog::new();
        let mut views = ViewCatalog::new();
        let mut view_of_dataset = Vec::with_capacity(NUM_SALES_DATASETS);

        let n = NUM_SALES_DATASETS;
        let ratio = MAX_VIEW_BYTES as f64 / MIN_VIEW_BYTES as f64;
        // Projection cache sizes, log-spaced.
        let view_sizes: Vec<u64> = (0..n)
            .map(|i| {
                let frac = i as f64 / (n - 1) as f64;
                (MAX_VIEW_BYTES as f64 / ratio.powf(frac)).round() as u64
            })
            .collect();
        let view_total: f64 = view_sizes.iter().map(|&b| b as f64).sum();
        // Scale disk sizes so the catalog totals 600 GB.
        let disk_scale = (600.0 * GB as f64) / view_total;

        // Schema names cycle through the three TPC-DS sales tables.
        const SCHEMAS: [&str; 3] = ["store_sales", "catalog_sales", "web_sales"];
        for (i, &vbytes) in view_sizes.iter().enumerate() {
            let name = format!("{}_{:02}", SCHEMAS[i % 3], i);
            let disk = (vbytes as f64 * disk_scale).round() as u64;
            let d = datasets.add(&name, disk);
            let v = views.add(
                &format!("{name}_proj"),
                d,
                ViewKind::VerticalProjection,
                vbytes,
                vbytes, // projected columns on disk ≈ cached footprint
            );
            view_of_dataset.push(v);
        }

        Self {
            datasets,
            views,
            view_of_dataset,
        }
    }

    /// The projection view over dataset `d`.
    pub fn view_for(&self, d: DatasetId) -> ViewId {
        self.view_of_dataset[d.0]
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn figure3_profile() {
        let cat = SalesCatalog::build();
        assert_eq!(cat.datasets.len(), 30);
        assert_eq!(cat.views.len(), 30);
        let sizes: Vec<u64> = cat.views.iter().map(|v| v.cached_bytes).collect();
        assert_eq!(*sizes.iter().max().unwrap(), MAX_VIEW_BYTES);
        assert_eq!(*sizes.iter().min().unwrap(), MIN_VIEW_BYTES);
        // Monotone decreasing (dataset 0 is largest).
        for w in sizes.windows(2) {
            assert!(w[0] >= w[1]);
        }
    }

    #[test]
    fn disk_total_is_600gb() {
        let cat = SalesCatalog::build();
        let total = cat.datasets.total_bytes() as f64 / GB as f64;
        assert!((total - 600.0).abs() < 1.0, "total={total} GB");
    }

    #[test]
    fn views_map_to_datasets() {
        let cat = SalesCatalog::build();
        for d in cat.datasets.iter() {
            let v = cat.views.get(cat.view_for(d.id));
            assert_eq!(v.dataset, d.id);
            assert!(v.cached_bytes < d.disk_bytes);
        }
    }
}
