//! On-disk datasets: the raw inputs queries scan. A dataset is identified
//! by an index into a [`DatasetCatalog`]; candidate *views* over datasets
//! (base tables or vertical projections) live in [`crate::domain::view`].

pub const KB: u64 = 1 << 10;
pub const MB: u64 = 1 << 20;
pub const GB: u64 = 1 << 30;

/// Index of a dataset within its catalog.
#[derive(Debug, Clone, Copy, PartialEq, Eq, PartialOrd, Ord, Hash)]
pub struct DatasetId(pub usize);

/// One on-disk dataset.
#[derive(Debug, Clone)]
pub struct Dataset {
    pub id: DatasetId,
    pub name: String,
    /// Bytes on disk (what a full scan reads when uncached).
    pub disk_bytes: u64,
}

/// An ordered collection of datasets.
#[derive(Debug, Clone, Default)]
pub struct DatasetCatalog {
    datasets: Vec<Dataset>,
}

impl DatasetCatalog {
    pub fn new() -> Self {
        Self::default()
    }

    pub fn add(&mut self, name: &str, disk_bytes: u64) -> DatasetId {
        let id = DatasetId(self.datasets.len());
        self.datasets.push(Dataset {
            id,
            name: name.to_string(),
            disk_bytes,
        });
        id
    }

    pub fn get(&self, id: DatasetId) -> &Dataset {
        &self.datasets[id.0]
    }

    pub fn by_name(&self, name: &str) -> Option<&Dataset> {
        self.datasets.iter().find(|d| d.name == name)
    }

    pub fn len(&self) -> usize {
        self.datasets.len()
    }

    pub fn is_empty(&self) -> bool {
        self.datasets.is_empty()
    }

    pub fn iter(&self) -> impl Iterator<Item = &Dataset> {
        self.datasets.iter()
    }

    pub fn total_bytes(&self) -> u64 {
        self.datasets.iter().map(|d| d.disk_bytes).sum()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn catalog_basics() {
        let mut cat = DatasetCatalog::new();
        let a = cat.add("store_sales_01", 20 * GB);
        let b = cat.add("web_sales_01", 5 * GB);
        assert_eq!(cat.len(), 2);
        assert_eq!(cat.get(a).name, "store_sales_01");
        assert_eq!(cat.get(b).disk_bytes, 5 * GB);
        assert_eq!(cat.by_name("web_sales_01").unwrap().id, b);
        assert!(cat.by_name("nope").is_none());
        assert_eq!(cat.total_bytes(), 25 * GB);
    }
}
