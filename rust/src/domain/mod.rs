//! Domain model: datasets, cacheable views, query classes, tenants, and
//! the tenant-utility estimation model of §2/§5.1.

pub mod dataset;
pub mod query;
pub mod sales;
pub mod tenant;
pub mod tpch;
pub mod utility;
pub mod view;

pub use dataset::{Dataset, DatasetCatalog, DatasetId, GB, MB};
pub use query::{Query, QueryId};
pub use tenant::{Tenant, TenantId, TenantSet};
pub use utility::{BatchIndex, BatchUtilities, UtilityModel, WelfareTemplate};
pub use view::{View, ViewCatalog, ViewId, ViewKind};
