//! Queries: one submitted unit of work. A query belongs to a tenant,
//! arrives at a point in (simulated) time, reads a set of datasets, and —
//! per the candidate-view generation — can be answered off a set of
//! candidate views if they are all cached (§5.1's all-or-nothing model).

use crate::domain::tenant::TenantId;
use crate::domain::view::ViewId;
use crate::util::mask::ConfigMask;

/// Globally unique query identifier within a run.
#[derive(Debug, Clone, Copy, PartialEq, Eq, PartialOrd, Ord, Hash)]
pub struct QueryId(pub u64);

/// One query instance.
#[derive(Debug, Clone)]
pub struct Query {
    pub id: QueryId,
    pub tenant: TenantId,
    /// Simulated submission time (seconds).
    pub arrival: f64,
    /// Template/label for reporting (e.g. "tpch-q5", "sales-scan-12").
    pub template: String,
    /// Candidate views that must ALL be cached for this query to benefit.
    pub required_views: Vec<ViewId>,
    /// Bytes of disk I/O the query performs when nothing is cached — the
    /// utility it receives when its views are cached (I/O savings, §2).
    pub bytes_read: u64,
    /// Non-I/O compute cost in core-seconds (aggregation, joins); gives
    /// TPC-H queries their heavier-than-scan execution profile in the
    /// simulator.
    pub compute_cost: f64,
}

impl Query {
    /// True if `cached` (indexed by ViewId) covers all required views.
    pub fn satisfied_by(&self, cached: &ConfigMask) -> bool {
        self.required_views.iter().all(|v| cached.get(v.0))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn satisfaction_is_all_or_nothing() {
        let q = Query {
            id: QueryId(1),
            tenant: TenantId(0),
            arrival: 0.0,
            template: "t".into(),
            required_views: vec![ViewId(0), ViewId(2)],
            bytes_read: 100,
            compute_cost: 1.0,
        };
        assert!(q.satisfied_by(&ConfigMask::from_bools(&[true, false, true])));
        assert!(!q.satisfied_by(&ConfigMask::from_bools(&[true, true, false])));
        assert!(!q.satisfied_by(&ConfigMask::from_bools(&[false, false, true])));
    }
}
