//! The unified driver front door: one builder (`Session`) through
//! which every ROBUS driver is constructed — serial replay, pipelined
//! replay, single-node online serving (real-clock or simulated), the
//! sharded replay federation, and federated serving. This replaces the
//! twelve `run`/`*_with`/`*_sim` free-function entry points that
//! accumulated across PRs 1–9 (each now a thin `#[deprecated]`
//! delegate, pinned bit-identical in
//! `rust/tests/session_conversion.rs`).
//!
//! The shape is the same for all four drivers:
//!
//! ```text
//! Session::replay(&universe, tenants, engine)
//!     .config(cfg)              // CoordinatorConfig (batch window, seed, ...)
//!     .tiers(spec)              // optional RAM+SSD TierSpec
//!     .pipelined(depth)         // optional: overlap solve with execute
//!     .telemetry(&tel)          // optional: default is Telemetry::off()
//!     .run(&mut generator, policy.as_ref())
//! ```
//!
//! - [`Session::replay`] — the batched §5.3 replay loop
//!   ([`Coordinator`]); `.pipelined(depth)` selects the overlapped
//!   solver ([`Coordinator::run_pipelined`] semantics, bit-identical).
//! - [`Session::serve`] — the single-node online service;
//!   `.sim()` switches to the deterministic simulated-clock driver,
//!   which also returns the underlying [`RunResult`].
//! - [`Session::federated`] — the sharded replay federation
//!   ([`ShardedCoordinator`]) with elastic membership.
//! - [`Session::serve_federated`] — real-clock federated serving;
//!   `.sim()` selects the deterministic driver.
//!
//! Tier budgets (`--ram-budget`/`--ssd-budget`) enter through
//! `.tiers(TierSpec)`, which writes the one shared
//! [`CommonConfig::tiers`] field every driver reads — there is no
//! per-driver tier plumbing to keep in sync. A builder without
//! `.tiers(..)` (or with an SSD budget of 0) runs the bit-identical
//! single-tier path.

use crate::alloc::Policy;
use crate::cache::tier::TierSpec;
use crate::cluster::federation::{FederationConfig, ShardedCoordinator};
use crate::cluster::metrics::ClusterResult;
use crate::cluster::serving::{
    serve_federated_impl, serve_federated_sim_impl, FederatedServeReport,
    ServeFederationConfig,
};
use crate::coordinator::loop_::{Coordinator, CoordinatorConfig, RunResult};
use crate::coordinator::service::{serve_impl, serve_sim_impl, ServeConfig, ServeReport};
use crate::domain::tenant::TenantSet;
use crate::sim::engine::SimEngine;
use crate::telemetry::Telemetry;
use crate::workload::generator::WorkloadGenerator;
use crate::workload::universe::Universe;

/// Entry point of the unified driver API. Each constructor returns the
/// builder for one driver family; see the module docs for the shape.
pub struct Session;

impl Session {
    /// Batched replay (the §5.3 experiment loop): a fixed number of
    /// batch windows over a seeded workload generator.
    pub fn replay(universe: &Universe, tenants: TenantSet, engine: SimEngine) -> Replay<'_> {
        Replay {
            universe,
            tenants,
            engine,
            config: CoordinatorConfig::default(),
            depth: None,
            tel: None,
        }
    }

    /// Single-node online serving on the real clock (per-tenant
    /// producer threads); `.sim()` switches to the deterministic
    /// simulated-clock driver.
    pub fn serve<'a>(
        universe: &'a Universe,
        tenants: &'a TenantSet,
        engine: &'a SimEngine,
    ) -> Serve<'a> {
        Serve {
            universe,
            tenants,
            engine,
            config: ServeConfig::default(),
            tel: None,
        }
    }

    /// Sharded replay federation with elastic membership.
    pub fn federated(
        universe: &Universe,
        tenants: TenantSet,
        engine: SimEngine,
    ) -> Federated<'_> {
        Federated {
            universe,
            tenants,
            engine,
            config: CoordinatorConfig::default(),
            fed: FederationConfig::default(),
            tel: None,
        }
    }

    /// Federated serving (live admission + reactive membership) on the
    /// real clock; `.sim()` switches to the deterministic driver.
    pub fn serve_federated<'a>(
        universe: &'a Universe,
        tenants: &'a TenantSet,
        engine: &'a SimEngine,
        fcfg: ServeFederationConfig,
    ) -> ServeFederated<'a> {
        ServeFederated {
            universe,
            tenants,
            engine,
            fcfg,
            tel: None,
        }
    }
}

/// Run `f` with the chosen telemetry handle, or an off handle when the
/// builder never saw `.telemetry(..)`.
fn with_tel<R>(tel: Option<&Telemetry>, f: impl FnOnce(&Telemetry) -> R) -> R {
    match tel {
        Some(t) => f(t),
        None => f(&Telemetry::off()),
    }
}

/// Builder for the batched replay drivers (serial and pipelined).
pub struct Replay<'a> {
    universe: &'a Universe,
    tenants: TenantSet,
    engine: SimEngine,
    config: CoordinatorConfig,
    depth: Option<usize>,
    tel: Option<&'a Telemetry>,
}

impl<'a> Replay<'a> {
    /// Replace the whole coordinator configuration (batch window,
    /// batch count, seed, γ, warm starts, tiers).
    pub fn config(mut self, config: CoordinatorConfig) -> Self {
        self.config = config;
        self
    }

    /// Run with a two-tier (RAM + SSD) cache under `spec`.
    pub fn tiers(mut self, spec: TierSpec) -> Self {
        self.config.common.tiers = Some(spec);
        self
    }

    /// Overlap the solve for batch b+1 with the execution of batch b
    /// (`depth` bounds the solver's run-ahead; 0 clamps to 1). The
    /// results stay bit-identical to the serial loop.
    pub fn pipelined(mut self, depth: usize) -> Self {
        self.depth = Some(depth);
        self
    }

    /// Attach a telemetry handle (default: off).
    pub fn telemetry(mut self, tel: &'a Telemetry) -> Self {
        self.tel = Some(tel);
        self
    }

    /// Drive the loop to completion over `generator`'s arrivals.
    pub fn run(self, generator: &mut WorkloadGenerator, policy: &dyn Policy) -> RunResult {
        let coord = Coordinator::new(self.universe, self.tenants, self.engine, self.config);
        with_tel(self.tel, |tel| match self.depth {
            Some(depth) => coord.run_pipelined_impl(generator, policy, depth, tel),
            None => coord.run_impl(generator, policy, tel),
        })
    }
}

/// Builder for single-node online serving.
pub struct Serve<'a> {
    universe: &'a Universe,
    tenants: &'a TenantSet,
    engine: &'a SimEngine,
    config: ServeConfig,
    tel: Option<&'a Telemetry>,
}

impl<'a> Serve<'a> {
    /// Replace the whole serve configuration (duration, rate, batch
    /// window, admission policy, seed, tiers, ...).
    pub fn config(mut self, config: ServeConfig) -> Self {
        self.config = config;
        self
    }

    /// Run with a two-tier (RAM + SSD) cache under `spec`.
    pub fn tiers(mut self, spec: TierSpec) -> Self {
        self.config.common.tiers = Some(spec);
        self
    }

    /// Attach a telemetry handle (default: off).
    pub fn telemetry(mut self, tel: &'a Telemetry) -> Self {
        self.tel = Some(tel);
        self
    }

    /// Switch to the deterministic simulated-clock driver, whose
    /// result also carries the underlying [`RunResult`].
    pub fn sim(self) -> ServeSim<'a> {
        ServeSim(self)
    }

    /// Serve on the real clock until the configured duration elapses
    /// and all admitted traffic is drained.
    pub fn run(self, policy: &dyn Policy) -> ServeReport {
        with_tel(self.tel, |tel| {
            serve_impl(self.universe, self.tenants, self.engine, policy, &self.config, tel)
        })
    }
}

/// The simulated-clock variant of [`Serve`] (see [`Serve::sim`]).
pub struct ServeSim<'a>(Serve<'a>);

impl ServeSim<'_> {
    /// Drive the same serving loop on a simulated clock: every result
    /// is a pure function of the configuration.
    pub fn run(self, policy: &dyn Policy) -> (ServeReport, RunResult) {
        let s = self.0;
        with_tel(s.tel, |tel| {
            serve_sim_impl(s.universe, s.tenants, s.engine, policy, &s.config, tel)
        })
    }
}

/// Builder for the sharded replay federation.
pub struct Federated<'a> {
    universe: &'a Universe,
    tenants: TenantSet,
    engine: SimEngine,
    config: CoordinatorConfig,
    fed: FederationConfig,
    tel: Option<&'a Telemetry>,
}

impl<'a> Federated<'a> {
    /// Replace the coordinator configuration shared with the
    /// single-node replay loop.
    pub fn config(mut self, config: CoordinatorConfig) -> Self {
        self.config = config;
        self
    }

    /// Replace the federation knobs (shard count, placement,
    /// replication, membership schedule, workers, ...).
    pub fn federation(mut self, fed: FederationConfig) -> Self {
        self.fed = fed;
        self
    }

    /// Run with a two-tier (RAM + SSD) cache: every shard gets a
    /// `spec.split(N')` slice, re-split on membership changes.
    pub fn tiers(mut self, spec: TierSpec) -> Self {
        self.config.common.tiers = Some(spec);
        self
    }

    /// Attach a telemetry handle (default: off).
    pub fn telemetry(mut self, tel: &'a Telemetry) -> Self {
        self.tel = Some(tel);
        self
    }

    /// Drive the federated loop to completion.
    pub fn run(self, generator: &mut WorkloadGenerator, policy: &dyn Policy) -> ClusterResult {
        let coord = ShardedCoordinator::new(
            self.universe,
            self.tenants,
            self.engine,
            self.config,
            self.fed,
        );
        with_tel(self.tel, |tel| coord.run_impl(generator, policy, tel))
    }
}

/// Builder for federated serving.
pub struct ServeFederated<'a> {
    universe: &'a Universe,
    tenants: &'a TenantSet,
    engine: &'a SimEngine,
    fcfg: ServeFederationConfig,
    tel: Option<&'a Telemetry>,
}

impl<'a> ServeFederated<'a> {
    /// Run with a two-tier (RAM + SSD) cache: every shard gets a
    /// `spec.split(N')` slice, re-split on reactive membership events.
    pub fn tiers(mut self, spec: TierSpec) -> Self {
        self.fcfg.serve.common.tiers = Some(spec);
        self
    }

    /// Attach a telemetry handle (default: off).
    pub fn telemetry(mut self, tel: &'a Telemetry) -> Self {
        self.tel = Some(tel);
        self
    }

    /// Switch to the deterministic simulated-clock driver.
    pub fn sim(self) -> ServeFederatedSim<'a> {
        ServeFederatedSim(self)
    }

    /// Serve on the real clock with per-tenant producer threads.
    pub fn run(self, policy: &dyn Policy) -> FederatedServeReport {
        with_tel(self.tel, |tel| {
            serve_federated_impl(self.universe, self.tenants, self.engine, policy, &self.fcfg, tel)
        })
    }
}

/// The simulated-clock variant of [`ServeFederated`]
/// (see [`ServeFederated::sim`]).
pub struct ServeFederatedSim<'a>(ServeFederated<'a>);

impl ServeFederatedSim<'_> {
    /// Drive the same federated serving loop on a simulated clock.
    pub fn run(self, policy: &dyn Policy) -> FederatedServeReport {
        let s = self.0;
        with_tel(s.tel, |tel| {
            serve_federated_sim_impl(s.universe, s.tenants, s.engine, policy, &s.fcfg, tel)
        })
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::alloc::PolicyKind;
    use crate::cache::tier::{TierBudgets, TierCostModel};
    use crate::coordinator::loop_::CommonConfig;
    use crate::sim::cluster::ClusterConfig;
    use crate::workload::spec::{AccessSpec, TenantSpec, WindowSpec};

    fn quick_cfg() -> CoordinatorConfig {
        CoordinatorConfig {
            common: CommonConfig {
                batch_secs: 30.0,
                seed: 11,
                ..CommonConfig::default()
            },
            n_batches: 3,
        }
    }

    fn gen(universe: &Universe) -> WorkloadGenerator {
        let specs: Vec<TenantSpec> = (1..=2)
            .map(|g| TenantSpec::new(AccessSpec::g(g), 10.0).with_window(WindowSpec::default()))
            .collect();
        WorkloadGenerator::new(specs, universe, 11)
    }

    #[test]
    #[cfg_attr(miri, ignore)]
    fn replay_serial_and_pipelined_agree() {
        let universe = Universe::sales_only();
        let engine = SimEngine::new(ClusterConfig::default());
        let policy = PolicyKind::FastPf.build();
        let serial = Session::replay(&universe, TenantSet::equal(2), engine.clone())
            .config(quick_cfg())
            .run(&mut gen(&universe), policy.as_ref());
        let pipelined = Session::replay(&universe, TenantSet::equal(2), engine)
            .config(quick_cfg())
            .pipelined(2)
            .run(&mut gen(&universe), policy.as_ref());
        assert_eq!(serial.end_time, pipelined.end_time);
        assert_eq!(serial.outcomes.len(), pipelined.outcomes.len());
    }

    #[test]
    #[cfg_attr(miri, ignore)]
    fn tiers_builder_threads_spec_into_the_run() {
        let universe = Universe::sales_only();
        let engine = SimEngine::new(ClusterConfig::default());
        let policy = PolicyKind::FastPf.build();
        let spec = TierSpec {
            budgets: TierBudgets {
                ram: engine.config.cache_budget / 2,
                ssd: engine.config.cache_budget,
            },
            cost: TierCostModel::default(),
        };
        let r = Session::replay(&universe, TenantSet::equal(2), engine)
            .config(quick_cfg())
            .tiers(spec)
            .run(&mut gen(&universe), policy.as_ref());
        // The SSD plane exists in the records (it may be empty early).
        assert_eq!(r.batches.len(), 3);
        assert!(r
            .batches
            .iter()
            .all(|b| b.ssd.n_bits() == universe.views.len()));
    }
}
