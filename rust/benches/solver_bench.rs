//! Solver microbenchmarks (`cargo bench --bench solver_bench`):
//! the per-batch allocation hot path, solver by solver — the numbers
//! behind the paper's §5.4 claim that view-selection wait times are
//! "of the order of tens of milliseconds".
//!
//! Uses the in-repo criterion-style harness (util::bench); the offline
//! registry has no criterion crate. Results are also written to
//! `BENCH_solver.json` (ns/iter for configuration-space pruning, the MW
//! solves, and a full coordinator batch) so successive PRs can track the
//! performance trajectory mechanically.

use robus::alloc::config_space::ConfigSpace;
use robus::alloc::fastpf::FastPf;
use robus::alloc::mmf::MaxMinFair;
use robus::alloc::mmf_mw::SimpleMmfMw;
use robus::alloc::pf_mw::PfMw;
use robus::alloc::rsd::RandomSerialDictatorship;
use robus::alloc::{Policy, PolicyKind};
use robus::cache::tier::{TierBudgets, TierCostModel, TierSpec};
use robus::coordinator::loop_::{CommonConfig, Coordinator, CoordinatorConfig, RunResult};
use robus::domain::tenant::TenantSet;
use robus::experiments::analysis::random_sales_batch;
use robus::runtime::solvers::{AcceleratedFastPf, CompiledSolvers};
use robus::session::Session;
use robus::sim::cluster::ClusterConfig;
use robus::sim::engine::SimEngine;
use robus::solver::gradient::GradientConfig;
use robus::util::bench::BenchSuite;
use robus::util::json::Json;
use robus::util::rng::Pcg64;
use robus::util::stats;
use robus::workload::generator::WorkloadGenerator;
use robus::workload::spec::{AccessSpec, TenantSpec, WindowSpec};
use robus::workload::universe::Universe;

fn main() {
    let mut suite = BenchSuite::new("solver microbenchmarks");
    let mut rng = Pcg64::new(99);
    let batch4 = random_sales_batch(4, &mut rng);
    let batch8 = random_sales_batch(8, &mut rng);

    // WELFARE oracle (exact knapsack) — the inner loop of everything.
    suite.bench("welfare_exact_knapsack_n4", || {
        batch4
            .welfare_problem(&[1.0, 0.5, 0.25, 0.125])
            .solve_exact()
            .value
    });
    suite.bench("welfare_greedy_n4", || {
        batch4
            .welfare_problem(&[1.0, 0.5, 0.25, 0.125])
            .solve_greedy()
            .value
    });
    // Template path: values rewritten in place, skeleton reused.
    let mut template = batch4.welfare_template();
    suite.bench("welfare_template_solve_n4", || {
        template.solve(&[1.0, 0.5, 0.25, 0.125]).value
    });

    // Mask-based utility evaluation (BatchIndex subset tests).
    let all_views = vec![true; batch4.n_views()];
    let full_mask = robus::alloc::ConfigMask::from_bools(&all_views);
    suite.bench("scaled_utilities_mask_n4", || {
        batch4.scaled_utilities(&full_mask).len()
    });

    // Configuration pruning (50 random weight vectors, §4.3).
    suite.bench("config_pruning_50vec_n4", || {
        let mut r = Pcg64::new(5);
        ConfigSpace::pruned(&batch4, 50, &mut r).len()
    });

    // Solver cores over a fixed pruned space.
    let space = ConfigSpace::pruned(&batch4, 50, &mut Pcg64::new(5));
    suite.bench("fastpf_gradient_solve_only", || {
        FastPf::solve_over(&space, &batch4, &GradientConfig::default())
    });
    suite.bench("mmf_lexicographic_lp_only", || {
        MaxMinFair::solve_over(&space, &batch4)
    });

    // Full per-batch allocations, policy by policy.
    for kind in [
        PolicyKind::Static,
        PolicyKind::Optp,
        PolicyKind::Mmf,
        PolicyKind::FastPf,
    ] {
        let policy = kind.build();
        let name = format!("policy_allocate_{}_n4", kind.name());
        suite.bench(&name, || {
            let mut r = Pcg64::new(7);
            policy.allocate(&batch4, &mut r).configs.len()
        });
    }

    // RSD: exact permutation enumeration at n=4, sampling at n=8.
    let rsd = RandomSerialDictatorship::default();
    suite.bench("rsd_exact_n4", || {
        let mut r = Pcg64::new(8);
        rsd.allocate(&batch4, &mut r).configs.len()
    });
    let rsd_sampled = RandomSerialDictatorship {
        exact_up_to: 0,
        samples: 64,
    };
    suite.bench("rsd_sampled64_n8", || {
        let mut r = Pcg64::new(8);
        rsd_sampled.allocate(&batch8, &mut r).configs.len()
    });

    // Provably-good MW algorithms (capped iteration budgets).
    let mmf_mw = SimpleMmfMw::default();
    suite.bench("simplemmf_mw_n4", || mmf_mw.solve(&batch4).len());
    let pf_mw = PfMw {
        epsilon: 0.2,
        max_iters: 120,
        search_steps: 6,
    };
    suite.bench("pf_mw_feasibility_search_n4", || pf_mw.solve(&batch4).len());

    // One full coordinator batch: workload generation → batch-problem
    // build → FASTPF solve → cache update → simulated execution.
    let universe = Universe::sales_only();
    let tenants = TenantSet::equal(4);
    let engine = SimEngine::new(ClusterConfig::default());
    let coord_cfg = CoordinatorConfig {
        common: CommonConfig {
            batch_secs: 40.0,
            seed: 7,
            ..CommonConfig::default()
        },
        n_batches: 1,
    };
    let coordinator = Coordinator::new(&universe, tenants, engine, coord_cfg);
    let window = WindowSpec {
        mean_secs: 120.0,
        std_secs: 30.0,
        candidates: 8,
    };
    let specs: Vec<TenantSpec> = (1..=4)
        .map(|g| TenantSpec::new(AccessSpec::g(g), 20.0).with_window(window.clone()))
        .collect();
    let fastpf = PolicyKind::FastPf.build();
    suite.bench("coordinator_full_batch_fastpf_n4", || {
        let mut gen = WorkloadGenerator::new(specs.clone(), &universe, 7);
        // The coordinator is shared across iterations so only the batch
        // itself is timed; the deprecated entry point is the thin
        // delegate of `run_impl`, identical cost.
        #[allow(deprecated)]
        let run = coordinator.run(&mut gen, fastpf.as_ref());
        run.outcomes.len()
    });

    // Compiled (PJRT) FASTPF — one execute per batch, including padding
    // and marshalling. Executable cache warmed outside the timed region.
    match CompiledSolvers::open_default() {
        Ok(solvers) => {
            let accel = AcceleratedFastPf(solvers);
            let mut r = Pcg64::new(9);
            let _ = accel.allocate(&batch4, &mut r);
            suite.bench("fastpf_compiled_pjrt_n4", || {
                let mut r = Pcg64::new(9);
                accel.allocate(&batch4, &mut r).configs.len()
            });
        }
        Err(e) => eprintln!("skipping compiled-solver bench: {e}"),
    }

    // Steady-state per-batch solve latency, cold vs warm-started, on
    // the real serial driver (`solve_secs` is the executor's per-batch
    // solve host time). Same workload seeds both ways, so the carried
    // `WarmState` is the only difference between the two columns.
    let solve_ns_for = |warm_start: bool| -> Vec<f64> {
        let cfg = CoordinatorConfig {
            common: CommonConfig {
                batch_secs: 40.0,
                seed: 7,
                warm_start,
                ..CommonConfig::default()
            },
            n_batches: 30,
        };
        let mut out = Vec::new();
        for pass in 0..3u64 {
            let mut gen = WorkloadGenerator::new(specs.clone(), &universe, 7 + pass);
            let run = Session::replay(
                &universe,
                TenantSet::equal(4),
                SimEngine::new(ClusterConfig::default()),
            )
            .config(cfg.clone())
            .run(&mut gen, fastpf.as_ref());
            out.extend(run.batches.iter().map(|b| b.solve_secs * 1e9));
        }
        out
    };
    let cold = solve_ns_for(false);
    let warm = solve_ns_for(true);
    let cold_ps = stats::percentiles_of(&cold, &[50.0, 99.0]);
    let warm_ps = stats::percentiles_of(&warm, &[50.0, 99.0]);
    let (p50_cold, p99_cold) = (cold_ps[0], cold_ps[1]);
    let (p50_warm, p99_warm) = (warm_ps[0], warm_ps[1]);
    let ratio = p50_warm / p50_cold.max(1.0);
    println!(
        "\nwarm-start fastpf solves over {} batches: cold p50 {:.0} ns / p99 {:.0} ns, \
         warm p50 {:.0} ns / p99 {:.0} ns (warm/cold p50 {:.3})",
        cold.len(),
        p50_cold,
        p99_cold,
        p50_warm,
        p99_warm,
        ratio,
    );

    // Tiered-uplift figure: the same workload and the same *total* cache
    // bytes, all-RAM vs a small RAM tier backed by a 20× larger SSD
    // plane (the production framing of the tier model). Fully simulated
    // → deterministic; `check_bench_regression.py` gates the retention
    // ratio so a collapsed tiered path can't land silently.
    let total = ClusterConfig::default().cache_budget;
    let tiered_run = |policy: &dyn Policy, tiers: Option<TierSpec>| -> RunResult {
        let cfg = CoordinatorConfig {
            common: CommonConfig {
                batch_secs: 40.0,
                seed: 7,
                tiers,
                ..CommonConfig::default()
            },
            n_batches: 8,
        };
        let mut gen = WorkloadGenerator::new(specs.clone(), &universe, 7);
        Session::replay(
            &universe,
            TenantSet::equal(4),
            SimEngine::new(ClusterConfig::default()),
        )
        .config(cfg)
        .run(&mut gen, policy)
    };
    let qpm = |r: &RunResult| r.outcomes.len() as f64 / r.end_time.max(1e-9) * 60.0;
    let static_baseline = tiered_run(PolicyKind::Static.build().as_ref(), None);
    let ram_only = tiered_run(fastpf.as_ref(), Some(TierSpec::single(total)));
    let ram_ssd = tiered_run(
        fastpf.as_ref(),
        Some(TierSpec {
            budgets: TierBudgets {
                ram: total / 21,
                ssd: total - total / 21,
            },
            cost: TierCostModel::default(),
        }),
    );
    let retention = qpm(&ram_ssd) / qpm(&ram_only).max(1e-9);
    println!(
        "\ntiered uplift at equal total bytes ({total} B): RAM-only {:.1} q/min vs \
         RAM+20×SSD {:.1} q/min (retention {:.3})",
        qpm(&ram_only),
        qpm(&ram_ssd),
        retention,
    );

    println!("\n{}", suite.markdown());
    let mut doc = suite.to_json();
    doc.set(
        "tiered",
        Json::from_pairs(vec![
            ("total_bytes", Json::Number(total as f64)),
            ("ram_only_qpm", Json::Number(qpm(&ram_only))),
            ("ram_ssd_qpm", Json::Number(qpm(&ram_ssd))),
            ("ram_ssd_over_ram_only", Json::Number(retention)),
            (
                "ram_only_fairness_spread",
                Json::Number(robus::cluster::speedup_spread(&ram_only, &static_baseline)),
            ),
            (
                "ram_ssd_fairness_spread",
                Json::Number(robus::cluster::speedup_spread(&ram_ssd, &static_baseline)),
            ),
        ]),
    );
    doc.set(
        "warm_start",
        Json::from_pairs(vec![
            ("solve_p50_cold_ns", Json::Number(p50_cold)),
            ("solve_p99_cold_ns", Json::Number(p99_cold)),
            ("solve_p50_warm_ns", Json::Number(p50_warm)),
            ("solve_p99_warm_ns", Json::Number(p99_warm)),
            ("p50_warm_over_cold", Json::Number(ratio)),
        ]),
    );
    match std::fs::write("BENCH_solver.json", doc.to_string_pretty()) {
        Ok(()) => println!("(wrote BENCH_solver.json)"),
        Err(e) => eprintln!("warn: could not write BENCH_solver.json: {e}"),
    }
}
