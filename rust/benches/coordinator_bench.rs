//! End-to-end coordinator benchmarks (`cargo bench --bench
//! coordinator_bench`): full multi-batch runs through generation →
//! solve → cache transition → simulated execution, serial vs pipelined.
//!
//! Besides the usual ns/iter suite, this writes
//! `BENCH_coordinator.json` with the service-level numbers the
//! trajectory tracks: batches/sec, p50/p99 solve latency, and the
//! pipeline stall fraction (share of host wall-clock the executor spent
//! waiting on solves — ≈1 serial, → 0 as the pipeline hides the solve).

use robus::alloc::{Policy, PolicyKind};
use robus::coordinator::RunResult;
use robus::experiments::runner::{run_with_policies_pipelined, run_with_policies_serial};
use robus::experiments::setups;
use robus::util::bench::BenchSuite;
use robus::util::json::Json;

fn policies() -> Vec<Box<dyn Policy>> {
    vec![PolicyKind::FastPf.build()]
}

fn run_detail(r: &RunResult, mode: &str, depth: usize) -> Json {
    let solve_ps = r.solve_ms_percentiles(&[50.0, 99.0]);
    Json::from_pairs(vec![
        ("mode", Json::String(mode.to_string())),
        ("pipeline_depth", Json::Number(depth as f64)),
        ("policy", Json::String(r.policy.to_string())),
        ("batches", Json::Number(r.batches.len() as f64)),
        ("queries", Json::Number(r.outcomes.len() as f64)),
        ("host_wall_secs", Json::Number(r.host_wall_secs)),
        ("batches_per_sec", Json::Number(r.batches_per_sec())),
        ("solve_ms_p50", Json::Number(solve_ps[0])),
        ("solve_ms_p99", Json::Number(solve_ps[1])),
        ("stall_fraction", Json::Number(r.stall_fraction())),
        (
            "max_queue_depth",
            Json::Number(
                r.batches.iter().map(|b| b.queue_depth).max().unwrap_or(0) as f64,
            ),
        ),
    ])
}

fn main() {
    let mut suite = BenchSuite::new("coordinator end-to-end");
    // Sales G2, 10 batches, FASTPF: the §5.3 shape at bench-able size.
    let setup = setups::data_sharing_sales()[1].clone().quick(10);

    suite.bench("coordinator_serial_10b_fastpf", || {
        run_with_policies_serial(&setup, &policies()).runs[0]
            .outcomes
            .len()
    });
    suite.bench("coordinator_pipelined_d2_10b_fastpf", || {
        run_with_policies_pipelined(&setup, &policies(), 2).runs[0]
            .outcomes
            .len()
    });
    // The default four-policy comparison, serial, as the heavyweight
    // end-to-end reference point.
    suite.bench("experiment_4policy_serial_6b", || {
        let s = setups::data_sharing_sales()[0].clone().quick(6);
        let ps: Vec<Box<dyn Policy>> = robus::experiments::runner::default_policies()
            .into_iter()
            .map(|k| k.build())
            .collect();
        run_with_policies_serial(&s, &ps).runs.len()
    });

    // One instrumented run per mode for the service-level numbers.
    // `serial_warm` is the same setup with carried solver state — its
    // solve_ms_p50 against serial's is the end-to-end warm-start cut.
    let serial = run_with_policies_serial(&setup, &policies());
    let pipelined = run_with_policies_pipelined(&setup, &policies(), 2);
    let warm_setup = setup.clone().with_warm_start(true);
    let serial_warm = run_with_policies_serial(&warm_setup, &policies());
    let runs = Json::Array(vec![
        run_detail(&serial.runs[0], "serial", 0),
        run_detail(&pipelined.runs[0], "pipelined", 2),
        run_detail(&serial_warm.runs[0], "serial_warm", 0),
    ]);
    let report = Json::from_pairs(vec![
        ("suite", Json::String("coordinator end-to-end".to_string())),
        ("microbench", suite.to_json()),
        ("runs", runs),
    ]);

    println!("\n{}", suite.markdown());
    match std::fs::write("BENCH_coordinator.json", report.to_string_pretty()) {
        Ok(()) => println!("(wrote BENCH_coordinator.json)"),
        Err(e) => eprintln!("warn: could not write BENCH_coordinator.json: {e}"),
    }
}
