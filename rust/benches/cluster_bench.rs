//! Federation scaling benchmarks (`cargo bench --bench cluster_bench`):
//! the same §5.3 Sales workload run through the sharded federation at
//! 1/2/4/8 shards, against the single-node serial coordinator.
//!
//! Writes `BENCH_cluster.json` with the two trajectory figures the
//! roadmap tracks: batches/sec scaling (shard solves run concurrently
//! on smaller sub-batches, so throughput should grow superlinearly in
//! the solve-bound regime — the acceptance bar is ≥2× at 4 shards vs
//! 1 shard) and the global fairness spread (max/min weight-normalized
//! per-tenant speedup vs the STATIC baseline), which the global
//! accountant must keep close to the single-node value.

use robus::alloc::{Policy, PolicyKind};
use robus::cluster::FederationConfig;
use robus::experiments::runner::{run_federated, run_with_policies_serial};
use robus::experiments::setups;
use robus::util::bench::BenchSuite;
use robus::util::json::Json;

fn main() {
    let mut suite = BenchSuite::new("sharded cache federation");
    // Sales G2 (the Zipf-skew §5.3 family) at bench-able size.
    let setup = setups::data_sharing_sales()[1].clone().quick(10);
    let shard_counts = [1usize, 2, 4, 8];

    for &shards in &shard_counts {
        let fed = FederationConfig::with_shards(shards);
        let s = setup.clone();
        suite.bench(&format!("cluster_{shards}shard_10b_fastpf"), move || {
            let policy: Box<dyn Policy> = PolicyKind::FastPf.build();
            run_federated(&s, &fed, policy.as_ref()).run.outcomes.len()
        });
    }

    // Instrumented runs for the trajectory figures: STATIC single-node
    // as the speedup baseline, serial FASTPF as the batches/sec
    // reference, one federation run per shard count.
    let baseline = run_with_policies_serial(&setup, &[PolicyKind::Static.build()]);
    let single = run_with_policies_serial(&setup, &[PolicyKind::FastPf.build()]);
    let results: Vec<_> = shard_counts
        .iter()
        .map(|&shards| {
            let fed = FederationConfig::with_shards(shards);
            let policy: Box<dyn Policy> = PolicyKind::FastPf.build();
            (shards, run_federated(&setup, &fed, policy.as_ref()))
        })
        .collect();
    let one_shard_bps = results[0].1.batches_per_sec();

    let scaling = Json::Array(
        results
            .iter()
            .map(|(shards, r)| {
                let mut row = r.to_json(Some(&baseline.runs[0]));
                row.set("shards", Json::Number(*shards as f64));
                row.set(
                    "speedup_vs_1shard",
                    Json::Number(r.batches_per_sec() / one_shard_bps.max(1e-12)),
                );
                row
            })
            .collect(),
    );
    let report = Json::from_pairs(vec![
        (
            "suite",
            Json::String("sharded cache federation".to_string()),
        ),
        ("workload", Json::String(setup.name.clone())),
        ("microbench", suite.to_json()),
        (
            "single_node_serial",
            Json::from_pairs(vec![
                (
                    "batches_per_sec",
                    Json::Number(single.runs[0].batches_per_sec()),
                ),
                (
                    "fairness_spread",
                    Json::Number(robus::cluster::speedup_spread(
                        &single.runs[0],
                        &baseline.runs[0],
                    )),
                ),
            ]),
        ),
        ("scaling", scaling),
    ]);

    println!("\n{}", suite.markdown());
    for (shards, r) in &results {
        println!(
            "{} shard(s): {:.2} batches/s ({:.2}x vs 1 shard), spread {:.3}",
            shards,
            r.batches_per_sec(),
            r.batches_per_sec() / one_shard_bps.max(1e-12),
            r.fairness_spread(&baseline.runs[0]),
        );
    }
    match std::fs::write("BENCH_cluster.json", report.to_string_pretty()) {
        Ok(()) => println!("(wrote BENCH_cluster.json)"),
        Err(e) => eprintln!("warn: could not write BENCH_cluster.json: {e}"),
    }
}
