//! Federation scaling benchmarks (`cargo bench --bench cluster_bench`):
//! the same §5.3 Sales workload run through the sharded federation at
//! 1–64 shards, against the single-node serial coordinator. The 16+
//! rungs exist to watch the shard runtime (DESIGN.md §2g): batches are
//! multiplexed over a fixed worker pool, so the ladder should flatten
//! at the host's core count rather than fall off a thread-spawn cliff.
//!
//! Writes `BENCH_cluster.json` with the trajectory figures the roadmap
//! tracks: batches/sec scaling (shard solves run concurrently on
//! smaller sub-batches, so throughput should grow superlinearly in the
//! solve-bound regime — the acceptance bar is ≥2× at 4 shards vs
//! 1 shard), the global fairness spread (max/min weight-normalized
//! per-tenant speedup vs the STATIC baseline), which the global
//! accountant must keep close to the single-node value, and the
//! **elasticity figure**: fairness-spread and throughput transients
//! before/during/after a live shard add and a shard kill on a
//! mid-length run.

use robus::alloc::{Policy, PolicyKind};
use robus::cache::tier::{TierBudgets, TierCostModel, TierSpec};
use robus::cluster::{
    AutoMembership, ClusterResult, FederationConfig, MembershipPlan, ServeFederationConfig,
};
use robus::coordinator::loop_::CommonConfig;
use robus::coordinator::ServeConfig;
use robus::domain::tenant::TenantSet;
use robus::experiments::runner::{run_federated, run_with_policies_serial};
use robus::experiments::setups;
use robus::session::Session;
use robus::sim::{ClusterConfig, SimEngine};
use robus::util::bench::BenchSuite;
use robus::util::json::Json;
use robus::workload::queue::AdmissionPolicy;
use robus::workload::Universe;

fn main() {
    let mut suite = BenchSuite::new("sharded cache federation");
    // Sales G2 (the Zipf-skew §5.3 family) at bench-able size.
    let setup = setups::data_sharing_sales()[1].clone().quick(10);
    // Timed microbenches stay on the small rungs; the instrumented
    // scaling figure below climbs the full ladder to 64 shards.
    let shard_counts = [1usize, 2, 4, 8];
    let scale_counts = [1usize, 2, 4, 8, 16, 32, 64];

    for &shards in &shard_counts {
        let fed = FederationConfig::with_shards(shards);
        let s = setup.clone();
        suite.bench(&format!("cluster_{shards}shard_10b_fastpf"), move || {
            let policy: Box<dyn Policy> = PolicyKind::FastPf.build();
            run_federated(&s, &fed, policy.as_ref()).run.outcomes.len()
        });
    }

    // Instrumented runs for the trajectory figures: STATIC single-node
    // as the speedup baseline, serial FASTPF as the batches/sec
    // reference, one federation run per shard count.
    let baseline = run_with_policies_serial(&setup, &[PolicyKind::Static.build()]);
    let single = run_with_policies_serial(&setup, &[PolicyKind::FastPf.build()]);
    let results: Vec<_> = scale_counts
        .iter()
        .map(|&shards| {
            let fed = FederationConfig::with_shards(shards);
            let policy: Box<dyn Policy> = PolicyKind::FastPf.build();
            (shards, run_federated(&setup, &fed, policy.as_ref()))
        })
        .collect();
    let one_shard_bps = results[0].1.batches_per_sec();

    let scaling = Json::Array(
        results
            .iter()
            .map(|(shards, r)| {
                let mut row = r.to_json(Some(&baseline.runs[0]));
                row.set("shards", Json::Number(*shards as f64));
                row.set(
                    "speedup_vs_1shard",
                    Json::Number(r.batches_per_sec() / one_shard_bps.max(1e-12)),
                );
                // Tail batch latency (solve + routing) per rung — the
                // p99 the scale-wall item tracks alongside batches/sec.
                row.set(
                    "solve_ms_p99",
                    Json::Number(r.run.solve_ms_percentiles(&[99.0])[0]),
                );
                row
            })
            .collect(),
    );
    // Elasticity figure: one 24-batch run with a live add and a kill;
    // per-event transient windows (spread + q/batch before/during/after
    // and the re-convergence lag) go into the report. The kill names an
    // *original* shard explicitly — the default victim would be the
    // fresh joiner, whose death merely reverts the add (the hash ring
    // is a pure function of the id set) and would understate the fault.
    // ROBUS_BENCH_QUICK (the CI bench mode) shrinks the run like it
    // shrinks the microbench iteration counts.
    let quick = std::env::var("ROBUS_BENCH_QUICK").is_ok();
    let (elastic_batches, elastic_plan) =
        if quick { (12, "add@3,kill:1@7") } else { (24, "add@6,kill:1@14") };
    let elastic_setup = setups::data_sharing_sales()[1].clone().quick(elastic_batches);
    let mut elastic_fed = FederationConfig::with_shards(4);
    elastic_fed.membership =
        MembershipPlan::parse(elastic_plan).expect("static plan parses");
    let elastic_policy: Box<dyn Policy> = PolicyKind::FastPf.build();
    let elastic = run_federated(&elastic_setup, &elastic_fed, elastic_policy.as_ref());
    let elasticity = Json::Array(
        elastic
            .membership_events()
            .iter()
            .map(|(b, c)| {
                let t = elastic.transient(*b, 4);
                Json::from_pairs(vec![
                    ("batch", Json::Number(*b as f64)),
                    ("action", Json::String(c.action.name().to_string())),
                    ("shard", Json::Number(c.shard as f64)),
                    ("views_moved", Json::Number(c.views_moved as f64)),
                    ("bytes_drained", Json::Number(c.bytes_drained as f64)),
                    ("bytes_lost", Json::Number(c.bytes_lost as f64)),
                    ("pre_spread", Json::Number(t.pre_spread)),
                    ("during_spread", Json::Number(t.during_spread)),
                    ("post_spread", Json::Number(t.post_spread)),
                    ("pre_qpb", Json::Number(t.pre_queries_per_batch)),
                    ("during_qpb", Json::Number(t.during_queries_per_batch)),
                    ("post_qpb", Json::Number(t.post_queries_per_batch)),
                    (
                        "recovery_batches",
                        match t.recovery_batches {
                            Some(d) => Json::Number(d as f64),
                            None => Json::Null,
                        },
                    ),
                ])
            })
            .collect(),
    );

    // Federated-serving figure (ISSUE 5): the real-clock serving loop
    // on its deterministic SimClock driver — host cost is admission
    // bookkeeping plus the per-batch shard solves, so completed-per-
    // host-second is the serving-path throughput the trajectory
    // tracks. Reactive membership runs with bounds that keep a steady
    // 2-shard federation stable (the soak-job assumption).
    // Run the serving figure twice — with the (default-on) warm-started
    // solves and with `--warm-start off` — so the trajectory records
    // the serving-path q/s uplift of carried solver state.
    let serve_universe = Universe::sales_only();
    let serve_tenants = TenantSet::equal(4);
    let serve_engine = SimEngine::new(ClusterConfig::default());
    let run_serving = |warm_start: bool| {
        let serve_cfg = ServeConfig {
            common: CommonConfig {
                batch_secs: 0.25,
                seed: 42,
                warm_start,
                ..CommonConfig::default()
            },
            duration_secs: if quick { 2.0 } else { 6.0 },
            rate_per_sec: 400.0,
            n_tenants: 4,
            queue_capacity: 16_384,
            admission: AdmissionPolicy::Drop,
            verbose: false,
        };
        let mut serve_fed = ServeFederationConfig::new(serve_cfg.clone(), 2);
        serve_fed.auto = Some(
            AutoMembership::parse("auto")
                .expect("static spec parses")
                .resolve(serve_cfg.rate_per_sec, 2)
                .expect("default bounds resolve"),
        );
        let serve_policy: Box<dyn Policy> = PolicyKind::FastPf.build();
        let t_serve = std::time::Instant::now();
        let served = Session::serve_federated(
            &serve_universe,
            &serve_tenants,
            &serve_engine,
            serve_fed,
        )
        .sim()
        .run(serve_policy.as_ref());
        (served, t_serve.elapsed().as_secs_f64())
    };
    let (served, serve_host_secs) = run_serving(true);
    let (served_cold, cold_host_secs) = run_serving(false);
    let warm_cphs = served.serve.completed as f64 / serve_host_secs.max(1e-9);
    let cold_cphs = served_cold.serve.completed as f64 / cold_host_secs.max(1e-9);
    let federated_serving = Json::from_pairs(vec![
        ("shards", Json::Number(2.0)),
        ("completed", Json::Number(served.serve.completed as f64)),
        ("batches", Json::Number(served.serve.batches as f64)),
        ("completed_per_host_sec", Json::Number(warm_cphs)),
        ("completed_per_host_sec_cold", Json::Number(cold_cphs)),
        (
            "warm_uplift",
            Json::Number(warm_cphs / cold_cphs.max(1e-9)),
        ),
        ("solve_ms_p99", Json::Number(served.serve.solve_ms_p99)),
        (
            "membership_events",
            Json::Number(served.membership_events().len() as f64),
        ),
        (
            "conserved",
            Json::Bool(served.serve.completed == served.serve.admitted),
        ),
        (
            "throughput_fairness",
            Json::Number(served.serve.throughput_fairness),
        ),
    ]);

    // Tiered-uplift figure at the federation level (ISSUE 10): the same
    // 4-shard §5.3 run at equal *total* cache bytes, all-RAM vs a small
    // RAM tier backed by a 20× larger SSD plane. Per-shard tier budgets
    // come from the federation's `TierSpec::split`, so this exercises
    // the tiered accountant and the demotion path under sharding; the
    // regression gate holds the retention ratio.
    let total = ClusterConfig::default().cache_budget;
    let tiered_fed_run = |tiers: Option<TierSpec>| {
        let s = setup.clone().with_tiers(tiers);
        let fed = FederationConfig::with_shards(4);
        let policy: Box<dyn Policy> = PolicyKind::FastPf.build();
        run_federated(&s, &fed, policy.as_ref())
    };
    let fed_qpm = |r: &ClusterResult| {
        r.run.outcomes.len() as f64 / r.run.end_time.max(1e-9) * 60.0
    };
    let tiered_ram_only = tiered_fed_run(Some(TierSpec::single(total)));
    let tiered_ram_ssd = tiered_fed_run(Some(TierSpec {
        budgets: TierBudgets {
            ram: total / 21,
            ssd: total - total / 21,
        },
        cost: TierCostModel::default(),
    }));
    let tiered_retention = fed_qpm(&tiered_ram_ssd) / fed_qpm(&tiered_ram_only).max(1e-9);
    println!(
        "tiered 4-shard uplift at equal total bytes ({total} B): RAM-only {:.1} q/min vs \
         RAM+20×SSD {:.1} q/min (retention {:.3})",
        fed_qpm(&tiered_ram_only),
        fed_qpm(&tiered_ram_ssd),
        tiered_retention,
    );
    let tiered = Json::from_pairs(vec![
        ("shards", Json::Number(4.0)),
        ("total_bytes", Json::Number(total as f64)),
        ("ram_only_qpm", Json::Number(fed_qpm(&tiered_ram_only))),
        ("ram_ssd_qpm", Json::Number(fed_qpm(&tiered_ram_ssd))),
        ("ram_ssd_over_ram_only", Json::Number(tiered_retention)),
        (
            "ram_only_fairness_spread",
            Json::Number(tiered_ram_only.fairness_spread(&baseline.runs[0])),
        ),
        (
            "ram_ssd_fairness_spread",
            Json::Number(tiered_ram_ssd.fairness_spread(&baseline.runs[0])),
        ),
    ]);

    let report = Json::from_pairs(vec![
        (
            "suite",
            Json::String("sharded cache federation".to_string()),
        ),
        ("workload", Json::String(setup.name.clone())),
        ("microbench", suite.to_json()),
        ("elasticity", elasticity),
        ("federated_serving", federated_serving),
        ("tiered", tiered),
        (
            "single_node_serial",
            Json::from_pairs(vec![
                (
                    "batches_per_sec",
                    Json::Number(single.runs[0].batches_per_sec()),
                ),
                (
                    "fairness_spread",
                    Json::Number(robus::cluster::speedup_spread(
                        &single.runs[0],
                        &baseline.runs[0],
                    )),
                ),
            ]),
        ),
        ("scaling", scaling),
    ]);

    println!("\n{}", suite.markdown());
    for (b, c) in elastic.membership_events() {
        let t = elastic.transient(b, 4);
        println!(
            "elasticity {}@{b}: spread {:.3} → {:.3} → {:.3}, recovery {:?}",
            c.action.name(),
            t.pre_spread,
            t.during_spread,
            t.post_spread,
            t.recovery_batches,
        );
    }
    for (shards, r) in &results {
        println!(
            "{} shard(s): {:.2} batches/s ({:.2}x vs 1 shard), spread {:.3}",
            shards,
            r.batches_per_sec(),
            r.batches_per_sec() / one_shard_bps.max(1e-12),
            r.fairness_spread(&baseline.runs[0]),
        );
    }
    match std::fs::write("BENCH_cluster.json", report.to_string_pretty()) {
        Ok(()) => println!("(wrote BENCH_cluster.json)"),
        Err(e) => eprintln!("warn: could not write BENCH_cluster.json: {e}"),
    }
}
