//! End-to-end benches (`cargo bench --bench table_benches`): one bench
//! per paper table/figure group, timing the full pipeline (workload
//! generation → batched coordinator → policy solve → simulated
//! execution → metrics) at reduced batch counts, plus the analysis
//! experiments (§4.3 pruning error, Lemma 1).
//!
//! These double as regeneration smoke tests: each bench runs the exact
//! code path `robus experiment <name>` uses for the corresponding table.

use robus::experiments::runner::run_experiment;
use robus::experiments::{analysis, setups};
use robus::util::bench::BenchSuite;

fn main() {
    let mut suite = BenchSuite::new("table/figure regeneration (6-batch runs)");

    let bench_setup = |suite: &mut BenchSuite, name: &str, setup: setups::ExperimentSetup| {
        let setup = setup.quick(6);
        suite.bench(name, || {
            let out = run_experiment(&setup);
            out.summaries.len()
        });
    };

    // Fig 5 / Tables 15-18 (one representative cell per group).
    bench_setup(&mut suite, "fig5_tables15_18_mixed_G2", setups::data_sharing_mixed().remove(1));
    // Fig 6 / Tables 19-22.
    bench_setup(&mut suite, "fig6_tables19_22_sales_G2", setups::data_sharing_sales().remove(1));
    // Fig 8 / Tables 23-25.
    bench_setup(&mut suite, "fig8_tables23_25_arrival_high", setups::arrival_rates().remove(2));
    // Fig 10 / Tables 26-28.
    bench_setup(&mut suite, "fig10_tables26_28_tenants_8", setups::tenant_scaling().remove(2));
    // Fig 11.
    bench_setup(&mut suite, "fig11_convergence", setups::convergence());
    // Fig 12 (one stateful cell).
    let (batch_setup, _) = setups::batch_size_sweep().remove(3);
    bench_setup(&mut suite, "fig12_batch40_stateful", batch_setup);

    // §4.3 pruning-error sweep (scaled down).
    suite.bench("sec4_3_pruning_error_m25", || {
        analysis::pruning_error(25, 10, 3)
    });

    // Lemma 1 grouped-instance comparison.
    suite.bench("lemma1_grouped_totals", || {
        analysis::grouped_instance_totals(&[3, 2, 1])
    });

    // Figure 3 catalog generation.
    suite.bench("fig3_sales_catalog", || {
        analysis::figure3_view_sizes_mb().len()
    });

    println!("\n{}", suite.markdown());
}
