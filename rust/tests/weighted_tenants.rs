//! Weighted-tenant end-to-end behaviour (§3.4): weights scale the SI
//! entitlement, the PF objective, the fair scheduler's slot shares, and
//! the Equation-5 fairness normalization.

use robus::alloc::{Policy, PolicyKind};
use robus::coordinator::loop_::{CommonConfig, CoordinatorConfig};
use robus::coordinator::metrics::fairness_index;
use robus::domain::tenant::TenantSet;
use robus::session::Session;
use robus::sim::cluster::ClusterConfig;
use robus::sim::engine::SimEngine;
use robus::workload::generator::WorkloadGenerator;
use robus::workload::spec::{AccessSpec, TenantSpec, WindowSpec};
use robus::workload::universe::Universe;

fn weighted_run(kind: PolicyKind, weights: &[f64], seed: u64) -> robus::coordinator::loop_::RunResult {
    let universe = Universe::sales_only();
    let mut tenants = TenantSet::new();
    for (i, &w) in weights.iter().enumerate() {
        tenants.add(&format!("t{i}"), w);
    }
    let engine = SimEngine::new(ClusterConfig::default());
    let config = CoordinatorConfig {
        common: CommonConfig {
            batch_secs: 40.0,
            seed,
            ..CommonConfig::default()
        },
        n_batches: 10,
    };
    let specs: Vec<TenantSpec> = (0..weights.len())
        .map(|i| {
            TenantSpec::new(AccessSpec::g(1 + i), 15.0).with_window(WindowSpec {
                mean_secs: 120.0,
                std_secs: 30.0,
                candidates: 8,
            })
        })
        .collect();
    let mut gen = WorkloadGenerator::new(specs, &universe, seed);
    let policy = kind.build();
    Session::replay(&universe, tenants, engine)
        .config(config)
        .run(&mut gen, policy.as_ref())
}

/// Weighted runs complete and produce weight-aware fairness indices in
/// [0, 1] for every policy.
#[test]
fn weighted_runs_complete_for_all_policies() {
    let weights = [1.0, 1.0, 1.5];
    let baseline = weighted_run(PolicyKind::Static, &weights, 7);
    for kind in [PolicyKind::Mmf, PolicyKind::FastPf, PolicyKind::Optp] {
        let run = weighted_run(kind, &weights, 7);
        assert_eq!(run.weights, weights.to_vec());
        let j = fairness_index(&run, &baseline);
        assert!(
            (0.0..=1.0 + 1e-9).contains(&j),
            "{}: fairness {j}",
            kind.name()
        );
        assert!(!run.outcomes.is_empty());
    }
}

/// In the per-batch allocation, a heavier tenant's SI entitlement
/// (λ_i/Σλ) is respected by the weighted-fair policies.
#[test]
fn weighted_si_entitlements() {
    use robus::domain::dataset::DatasetCatalog;
    use robus::domain::query::{Query, QueryId};
    use robus::domain::tenant::TenantId;
    use robus::domain::utility::BatchUtilities;
    use robus::domain::view::{ViewCatalog, ViewId, ViewKind};
    use robus::fairness::properties::sharing_incentive_violations;
    use robus::util::rng::Pcg64;

    // Two tenants, disjoint unit views, cache 1; weights 3:1.
    let mut ds = DatasetCatalog::new();
    let mut vc = ViewCatalog::new();
    for v in 0..2 {
        let d = ds.add(&format!("d{v}"), 100);
        vc.add(&format!("v{v}"), d, ViewKind::BaseTable, 100, 100);
    }
    let mut ts = TenantSet::new();
    let heavy = ts.add("heavy", 3.0);
    let light = ts.add("light", 1.0);
    let queries = vec![
        Query {
            id: QueryId(1),
            tenant: heavy,
            arrival: 0.0,
            template: "h".into(),
            required_views: vec![ViewId(0)],
            bytes_read: 10,
            compute_cost: 0.0,
        },
        Query {
            id: QueryId(2),
            tenant: light,
            arrival: 0.0,
            template: "l".into(),
            required_views: vec![ViewId(1)],
            bytes_read: 10,
            compute_cost: 0.0,
        },
    ];
    let batch = BatchUtilities::build(&ts, &vc, 100.0, &queries, None);
    for kind in [PolicyKind::Mmf, PolicyKind::FastPf] {
        let policy = kind.build();
        let alloc = policy.allocate(&batch, &mut Pcg64::new(1));
        let viol = sharing_incentive_violations(&alloc, &batch, 5e-3);
        assert!(viol.is_empty(), "{}: {viol:?}", kind.name());
        let v = alloc.expected_scaled_utilities(&batch);
        // Heavy tenant's view gets ~3/4 of the probability.
        assert!(
            (v[0] - 0.75).abs() < 0.02,
            "{}: heavy V = {} (expect ≈0.75)",
            kind.name(),
            v[0]
        );
    }
}
