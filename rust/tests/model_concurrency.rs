//! Exhaustive bounded model checks for the crate's three lock-free /
//! message-passing protocols (`--features model`; see
//! `rust/src/util/model.rs` and DESIGN.md §2i):
//!
//! 1. the serving router's RCU epoch publish/read — including the
//!    happens-before argument behind the `unsafe` deref in
//!    `ServeRouter::epoch`, and the ISSUE-mandated seeded mutation
//!    (`Release` publish weakened to `Relaxed`) shown to be *caught*
//!    as a data race;
//! 2. the worker pool's move-by-value job protocol (shared
//!    `Mutex<Receiver>` intake, reply channel), including worker-panic
//!    propagation;
//! 3. the trace writer's bounded-channel drop-and-count backpressure
//!    (records are dropped, never blocked on, and every record is
//!    accounted exactly once).
//!
//! Each protocol is modeled as a minimal *twin* built from the same
//! `util::sync` primitives the production code imports, with
//! [`RaceCell`] payloads standing in for the data the synchronization
//! is supposed to publish — the model checker detects a missing
//! happens-before edge as a data race on the payload. The production
//! types themselves run under the checker in
//! `cluster::serving::model_tests` (`cargo test --features model --lib`).

#![cfg(feature = "model")]

use std::panic::{catch_unwind, AssertUnwindSafe};
use std::sync::atomic::{AtomicU64 as StdAtomicU64, Ordering as StdOrdering};
use std::sync::Arc;

use robus::util::model::{self, QuietPanic, RaceCell};
use robus::util::sync::atomic::{AtomicPtr, Ordering};
use robus::util::sync::{mpsc, Mutex};

// ---------------------------------------------------------------------------
// 1. Router epoch publish/read (RCU pointer swap)
// ---------------------------------------------------------------------------

/// Twin of `RouterEpoch`: `version` is set before the model threads
/// start (visible by inheritance); `payload` is written *during* the
/// run, immediately before publication — exactly the data the
/// `Release` store is responsible for making visible.
struct Epoch {
    version: u64,
    payload: RaceCell<u64>,
}

fn payload_for(version: u64) -> u64 {
    version * 10 + 7
}

/// One publish/read round: main retains the epoch boxes (the append-only
/// `epochs` vec in production), writes each payload, publishes the
/// pointer with `publish_order`, while a spawned reader does
/// `Acquire`-load → deref → payload read.
fn epoch_protocol(publish_order: Ordering) {
    let slots: Vec<Box<Epoch>> = (1..=2u64)
        .map(|version| {
            Box::new(Epoch {
                version,
                payload: RaceCell::new(0),
            })
        })
        .collect();
    let current: Arc<AtomicPtr<Epoch>> = Arc::new(AtomicPtr::new(std::ptr::null_mut()));

    let reader_cur = Arc::clone(&current);
    let reader = model::spawn(move || {
        let mut last_version = 0u64;
        for _ in 0..2 {
            // ordering: Acquire pairs with the publisher's store below —
            // the protocol under test.
            let ptr = reader_cur.load(Ordering::Acquire);
            if ptr.is_null() {
                continue; // nothing published yet in this interleaving
            }
            // SAFETY (test): pointers stored into `current` point only
            // into boxes owned by `slots`, which outlives the reader
            // (main joins it before dropping the vec) — same retention
            // argument as `ServeRouter::epoch`.
            let ep = unsafe { &*ptr };
            assert!(ep.version >= last_version, "epoch went backwards");
            // The race-detected read: with a Release publish this is
            // ordered after the write; with Relaxed it is not.
            assert_eq!(ep.payload.read(), payload_for(ep.version));
            last_version = ep.version;
        }
    });

    for slot in slots.iter() {
        slot.payload.write(payload_for(slot.version));
        let ptr: *const Epoch = &**slot;
        current.store(ptr as *mut Epoch, publish_order);
    }
    reader.join().unwrap();
}

/// Every interleaving of a 2-epoch publish sequence against a reader:
/// with the production `Release` publish there is no data race, the
/// deref never sees a torn or stale payload, and versions observe
/// monotonically. `report.complete` pins that the exploration was
/// exhaustive within the preemption bound, not a sample.
#[test]
fn router_epoch_release_publish_has_no_races() {
    let report = model::check(|| epoch_protocol(Ordering::Release));
    assert!(report.complete, "epoch model must explore exhaustively");
    assert!(report.executions > 1, "expected multiple interleavings");
}

/// The ISSUE-mandated seeded mutation: weakening the epoch publish
/// from `Release` to `Relaxed` must be *caught*. The checker reports
/// it as a data race on the payload (the reader's deref is no longer
/// ordered after the publisher's write), which is exactly how the real
/// `ServeRouter::publish` regression would surface.
#[test]
fn router_epoch_relaxed_publish_mutation_is_caught() {
    let failure = catch_unwind(AssertUnwindSafe(|| {
        model::check(|| epoch_protocol(Ordering::Relaxed));
    }))
    .expect_err("Relaxed publish must fail the model check");
    let msg = failure.downcast_ref::<String>().cloned().unwrap_or_default();
    assert!(
        msg.contains("data race"),
        "expected a data-race report, got: {msg}"
    );
}

// ---------------------------------------------------------------------------
// 2. Worker pool move-by-value protocol
// ---------------------------------------------------------------------------

/// Job: (id, owned data, poison). The `Vec` moving through the channel
/// is the "move by value" under test — no aliasing, no copies.
type Job = (usize, Vec<u64>, bool);

enum Reply {
    Done(usize, u64),
    Panicked(usize),
}

/// Mirror of `util::pool` / `cluster::runtime`: N workers share one
/// `Mutex<Receiver>` intake (lock held across `recv`, as in
/// production), run each job under `catch_unwind`, and report on a
/// reply channel. Returns all replies once every worker exited on
/// intake disconnect.
fn pool_protocol(jobs: Vec<Job>) -> Vec<Reply> {
    let (job_tx, job_rx) = mpsc::channel::<Job>();
    let (reply_tx, reply_rx) = mpsc::channel::<Reply>();
    let intake = Arc::new(Mutex::new(job_rx));

    let workers: Vec<_> = (0..2)
        .map(|_| {
            let intake = Arc::clone(&intake);
            let reply_tx = reply_tx.clone();
            model::spawn(move || {
                loop {
                    let job = match intake.lock().unwrap().recv() {
                        Ok(job) => job,
                        Err(_) => break, // intake disconnected: pool drained
                    };
                    let (id, data, poison) = job;
                    let outcome = catch_unwind(AssertUnwindSafe(|| {
                        if poison {
                            std::panic::panic_any(QuietPanic("pool twin boom"));
                        }
                        data.iter().sum::<u64>()
                    }));
                    let reply = match outcome {
                        Ok(sum) => Reply::Done(id, sum),
                        Err(_) => Reply::Panicked(id),
                    };
                    if reply_tx.send(reply).is_err() {
                        break;
                    }
                }
            })
        })
        .collect();
    drop(reply_tx);

    for job in jobs {
        job_tx.send(job).unwrap();
    }
    drop(job_tx); // workers drain the queue, then exit

    let mut replies = Vec::new();
    while let Ok(reply) = reply_rx.recv() {
        replies.push(reply);
    }
    for w in workers {
        w.join().unwrap();
    }
    replies
}

/// Every interleaving of 2 workers × 2 jobs: each job's owned buffer
/// arrives at exactly one worker with its contents intact (the sums
/// prove the `Vec` round-tripped), every job is answered exactly once,
/// and the drain/disconnect shutdown never wedges or double-delivers.
#[test]
fn pool_moves_jobs_by_value_without_races() {
    let report = model::builder().max_executions(1_000_000).check(|| {
        let replies = pool_protocol(vec![(0, vec![1, 2, 3], false), (1, vec![10, 20], false)]);
        let mut sums = [None, None];
        for reply in replies {
            match reply {
                Reply::Done(id, sum) => {
                    assert!(sums[id].replace(sum).is_none(), "job {id} answered twice");
                }
                Reply::Panicked(id) => panic!("job {id} spuriously panicked"),
            }
        }
        assert_eq!(sums[0], Some(6));
        assert_eq!(sums[1], Some(30));
    });
    assert!(report.complete, "pool model must explore exhaustively");
}

/// Worker-panic propagation: a poisoned job's panic is contained by
/// the worker (reported as `Panicked`, mirroring the pool's repanic
/// protocol), and the sibling job's reply still arrives in every
/// interleaving — one tenant's panic cannot eat another's work.
#[test]
fn pool_propagates_worker_panics() {
    let report = model::builder().max_executions(1_000_000).check(|| {
        let replies = pool_protocol(vec![(0, vec![4, 5], false), (1, Vec::new(), true)]);
        assert_eq!(replies.len(), 2, "every job must be answered");
        let mut saw_done = false;
        let mut saw_panic = false;
        for reply in replies {
            match reply {
                Reply::Done(id, sum) => {
                    assert_eq!((id, sum), (0, 9));
                    saw_done = true;
                }
                Reply::Panicked(id) => {
                    assert_eq!(id, 1);
                    saw_panic = true;
                }
            }
        }
        assert!(saw_done && saw_panic);
    });
    assert!(report.complete, "panic model must explore exhaustively");
}

// ---------------------------------------------------------------------------
// 3. Trace writer drop-and-count backpressure
// ---------------------------------------------------------------------------

/// Twin of `telemetry::trace`: a producer `try_send`s records into a
/// bounded channel and counts drops instead of ever blocking; the
/// consumer drains until disconnect. In *every* interleaving the
/// accounting conserves records (`emitted == received`,
/// `emitted + dropped == total`), and across the exploration both
/// regimes — saturation drops and a drop-free fast consumer — are
/// actually reached (asserted via cross-execution counters, which use
/// raw `std` atomics so they stay invisible to the scheduler).
#[test]
fn trace_writer_drops_and_counts_conserve_records() {
    const RECORDS: u64 = 3;
    let saw_drops = Arc::new(StdAtomicU64::new(0));
    let saw_dropfree = Arc::new(StdAtomicU64::new(0));
    let (saw_drops_in, saw_dropfree_in) = (Arc::clone(&saw_drops), Arc::clone(&saw_dropfree));

    let report = model::check(move || {
        let (tx, rx) = mpsc::sync_channel::<u64>(1);
        let producer = model::spawn(move || {
            let (mut emitted, mut dropped) = (0u64, 0u64);
            for i in 0..RECORDS {
                match tx.try_send(i) {
                    Ok(()) => emitted += 1,
                    Err(mpsc::TrySendError::Full(_)) => dropped += 1,
                    Err(mpsc::TrySendError::Disconnected(_)) => {
                        panic!("receiver dropped before the producer finished")
                    }
                }
            }
            (emitted, dropped)
        });

        let mut received = 0u64;
        while rx.recv().is_ok() {
            received += 1;
        }
        let (emitted, dropped) = producer.join().unwrap();
        assert_eq!(emitted, received, "every accepted record is consumed");
        assert_eq!(emitted + dropped, RECORDS, "records conserve");
        if dropped > 0 {
            saw_drops_in.store(1, StdOrdering::Relaxed);
        } else {
            saw_dropfree_in.store(1, StdOrdering::Relaxed);
        }
    });
    assert!(report.complete, "trace model must explore exhaustively");
    let drops = saw_drops.load(StdOrdering::Relaxed);
    let dropfree = saw_dropfree.load(StdOrdering::Relaxed);
    assert_eq!(drops, 1, "no interleaving saturated the channel");
    assert_eq!(dropfree, 1, "no interleaving let the consumer keep up");
}
