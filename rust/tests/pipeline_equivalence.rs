//! The tentpole guarantee of the pipelined coordinator: overlapping the
//! solve for batch b+1 with the execution of batch b changes *nothing*
//! about the simulated run. For every setup family of the §5.3
//! experiment grid, the pipelined runner must produce bit-identical
//! `RunResult`s to the serial reference — same sampled configurations,
//! same cache transitions, same query outcomes, same summary metrics.
//! (Host-time observability fields — `solve_secs`, `stall_secs`,
//! `queue_depth`, `host_wall_secs` — are the only allowed differences.)

use robus::alloc::{Policy, PolicyKind};
use robus::experiments::runner::{
    default_policies, run_with_policies_pipelined, run_with_policies_serial,
};
use robus::experiments::setups::{self, ExperimentSetup};

fn policy_set() -> Vec<Box<dyn Policy>> {
    default_policies().into_iter().map(|k| k.build()).collect()
}

fn assert_setup_equivalent(setup: &ExperimentSetup, depth: usize) {
    let serial = run_with_policies_serial(setup, &policy_set());
    let pipelined = run_with_policies_pipelined(setup, &policy_set(), depth);
    assert_eq!(serial.runs.len(), pipelined.runs.len());
    for (s, p) in serial.runs.iter().zip(&pipelined.runs) {
        assert_eq!(s.policy, p.policy, "{}", setup.name);
        assert_eq!(s.end_time, p.end_time, "{}/{}", setup.name, s.policy);
        assert_eq!(s.outcomes.len(), p.outcomes.len(), "{}/{}", setup.name, s.policy);
        for (so, po) in s.outcomes.iter().zip(&p.outcomes) {
            assert_eq!(so.id, po.id);
            assert_eq!(so.tenant, po.tenant);
            assert_eq!(so.arrival, po.arrival);
            assert_eq!(so.start, po.start);
            assert_eq!(so.finish, po.finish);
            assert_eq!(so.from_cache, po.from_cache);
        }
        assert_eq!(s.batches.len(), p.batches.len());
        for (sb, pb) in s.batches.iter().zip(&p.batches) {
            assert_eq!(sb.index, pb.index);
            assert_eq!(sb.n_queries, pb.n_queries);
            assert_eq!(sb.config, pb.config, "{}/{}", setup.name, s.policy);
            assert_eq!(sb.cache_utilization, pb.cache_utilization);
            assert_eq!(sb.delta, pb.delta, "{}/{}", setup.name, s.policy);
            assert_eq!(sb.window_end, pb.window_end);
            assert_eq!(sb.exec_start, pb.exec_start);
            assert_eq!(sb.exec_end, pb.exec_end);
        }
    }
    for (s, p) in serial.summaries.iter().zip(&pipelined.summaries) {
        assert_eq!(s.throughput_per_min, p.throughput_per_min);
        assert_eq!(s.avg_cache_utilization, p.avg_cache_utilization);
        assert_eq!(s.hit_ratio, p.hit_ratio);
        assert_eq!(s.fairness_index, p.fairness_index);
    }
}

#[test]
fn grid_sales_data_sharing() {
    for setup in setups::data_sharing_sales() {
        assert_setup_equivalent(&setup.quick(3), 2);
    }
}

#[test]
fn grid_mixed_data_sharing() {
    // The mixed (TPC-H + Sales) universe is the heavy family; one cell
    // exercises the multi-view query classes under pipelining.
    let setup = setups::data_sharing_mixed()[1].clone().quick(3);
    assert_setup_equivalent(&setup, 2);
}

#[test]
fn grid_arrival_rates() {
    for setup in setups::arrival_rates() {
        assert_setup_equivalent(&setup.quick(3), 2);
    }
}

#[test]
fn grid_tenant_scaling() {
    for setup in setups::tenant_scaling() {
        assert_setup_equivalent(&setup.quick(3), 3);
    }
}

#[test]
fn grid_convergence_and_stateful() {
    assert_setup_equivalent(&setups::convergence().quick(4), 2);
    // A stateful (γ=2) Figure 12 cell: the planner's mirror must feed
    // the boost identically to the live cache.
    let (stateful, _gamma) = setups::batch_size_sweep()
        .into_iter()
        .find(|(s, g)| s.batch_secs == 20.0 && g.is_some())
        .expect("stateful 20s cell exists");
    assert_setup_equivalent(&stateful.quick(4), 2);
}

#[test]
fn deep_pipeline_still_identical() {
    // A depth far beyond the batch count: the solver plans the whole
    // run ahead; results still match the serial reference.
    let setup = setups::data_sharing_sales()[0].clone().quick(5);
    let policies: Vec<Box<dyn Policy>> = vec![PolicyKind::FastPf.build()];
    let serial = run_with_policies_serial(&setup, &policies);
    let pipelined = run_with_policies_pipelined(&setup, &policies, 64);
    for (s, p) in serial.runs.iter().zip(&pipelined.runs) {
        assert_eq!(s.outcomes.len(), p.outcomes.len());
        for (so, po) in s.outcomes.iter().zip(&p.outcomes) {
            assert_eq!(so.id, po.id);
            assert_eq!(so.finish, po.finish);
        }
        for (sb, pb) in s.batches.iter().zip(&p.batches) {
            assert_eq!(sb.config, pb.config);
        }
    }
}
