//! Cross-validation of the compiled (JAX/Pallas → HLO → PJRT) solvers
//! against the native Rust implementations on randomized batches — the
//! end-to-end guarantee that the three-layer stack computes the same
//! allocations as the reference algorithms.
//!
//! Requires `artifacts/` (make artifacts) *and* a PJRT-enabled build of
//! the runtime. With the stub backend (the offline default, see
//! `runtime::artifacts`), `open_default` fails and every test here
//! passes vacuously — the native solvers are covered by the rest of the
//! suite.

use robus::alloc::fastpf::FastPf;
use robus::alloc::{Policy, PolicyKind};
use robus::experiments::analysis::random_sales_batch;
use robus::fairness::properties::sharing_incentive_violations;
use robus::runtime::solvers::{AcceleratedFastPf, AcceleratedSimpleMmf, CompiledSolvers};
use robus::util::rng::Pcg64;

fn solvers() -> Option<CompiledSolvers> {
    match CompiledSolvers::open_default() {
        Ok(s) => Some(s),
        Err(e) => {
            eprintln!("skipping compiled-solver test: {e}");
            None
        }
    }
}

#[test]
fn compiled_pf_tracks_native_on_random_batches() {
    let Some(s) = solvers() else { return };
    let accel = AcceleratedFastPf(s);
    let native = FastPf::default();
    let mut rng = Pcg64::new(31);
    for case in 0..10 {
        let batch = random_sales_batch(2 + case % 4, &mut rng);
        if batch.active_tenants().is_empty() {
            continue;
        }
        let va = accel
            .allocate(&batch, &mut Pcg64::new(case as u64))
            .expected_scaled_utilities(&batch);
        let vn = native
            .allocate(&batch, &mut Pcg64::new(case as u64))
            .expected_scaled_utilities(&batch);
        for (i, (a, n)) in va.iter().zip(&vn).enumerate() {
            assert!(
                (a - n).abs() < 0.05,
                "case {case} tenant {i}: compiled {a} vs native {n}"
            );
        }
    }
}

#[test]
fn compiled_solvers_are_sharing_incentive() {
    let Some(s) = solvers() else { return };
    let mut rng = Pcg64::new(32);
    for case in 0..6 {
        let batch = random_sales_batch(3, &mut rng);
        if batch.active_tenants().len() < 2 {
            continue;
        }
        for policy in [
            &AcceleratedFastPf(s.clone()) as &dyn Policy,
            &AcceleratedSimpleMmf(s.clone()) as &dyn Policy,
        ] {
            let alloc = policy.allocate(&batch, &mut Pcg64::new(case));
            let viol = sharing_incentive_violations(&alloc, &batch, 0.05);
            assert!(
                viol.is_empty(),
                "{} case {case}: SI violations {viol:?}",
                policy.name()
            );
        }
    }
}

#[test]
fn compiled_pf_beats_static_minimum() {
    let Some(s) = solvers() else { return };
    let accel = AcceleratedFastPf(s);
    let static_p = PolicyKind::Static.build();
    let mut rng = Pcg64::new(33);
    let batch = random_sales_batch(4, &mut rng);
    let active = batch.active_tenants();
    let min_of = |v: &[f64]| active.iter().map(|&i| v[i]).fold(f64::INFINITY, f64::min);
    let v_accel = accel
        .allocate(&batch, &mut Pcg64::new(1))
        .expected_scaled_utilities(&batch);
    let v_static = static_p
        .allocate(&batch, &mut Pcg64::new(1))
        .expected_scaled_utilities(&batch);
    assert!(
        min_of(&v_accel) >= min_of(&v_static) - 0.05,
        "compiled PF min {} < STATIC min {}",
        min_of(&v_accel),
        min_of(&v_static)
    );
}
