//! Property-based integration tests: fairness invariants of the
//! policies over randomized batch problems (beyond the canonical
//! Tables 2-5 instances the unit tests pin down).

use robus::alloc::config_space::ConfigSpace;
use robus::alloc::{Policy, PolicyKind};
use robus::experiments::analysis::random_sales_batch;
use robus::fairness::properties::{
    find_blocking_coalition, find_pareto_improvement, sharing_incentive_violations,
};
use robus::util::proptest::{check, no_shrink};
use robus::util::rng::Pcg64;

/// All policies produce normalized, budget-feasible allocations on
/// random Sales batches.
#[test]
fn allocations_normalized_and_feasible() {
    check(
        25,
        |rng| random_sales_batch(2 + rng.index(5), rng),
        no_shrink,
        |batch| {
            for kind in [
                PolicyKind::Static,
                PolicyKind::Rsd,
                PolicyKind::Optp,
                PolicyKind::Mmf,
                PolicyKind::FastPf,
            ] {
                let policy = kind.build();
                let alloc = policy.allocate(batch, &mut Pcg64::new(1));
                if (alloc.total_probability() - 1.0).abs() > 1e-6 {
                    return Err(format!(
                        "{}: ||x|| = {}",
                        kind.name(),
                        alloc.total_probability()
                    ));
                }
                for c in &alloc.configs {
                    if batch.size_of(c) > batch.budget + 1e-6 {
                        return Err(format!("{}: config over budget", kind.name()));
                    }
                }
            }
            Ok(())
        },
    );
}

/// RSD, MMF and FASTPF are Sharing Incentive on random instances
/// (Table 6 rows 1/3/4).
#[test]
fn si_policies_meet_entitlements() {
    check(
        20,
        |rng| random_sales_batch(2 + rng.index(4), rng),
        no_shrink,
        |batch| {
            for kind in [PolicyKind::Rsd, PolicyKind::Mmf, PolicyKind::FastPf] {
                let policy = kind.build();
                let alloc = policy.allocate(batch, &mut Pcg64::new(2));
                let viol = sharing_incentive_violations(&alloc, batch, 5e-3);
                if !viol.is_empty() {
                    return Err(format!("{}: SI violations {viol:?}", kind.name()));
                }
            }
            Ok(())
        },
    );
}

/// FASTPF allocations admit no Pareto improvement and no blocking
/// coalition within a rich pruned space (the randomized core,
/// Theorem 2) on random instances.
#[test]
fn fastpf_core_on_random_instances() {
    check(
        12,
        |rng| random_sales_batch(2 + rng.index(3), rng),
        no_shrink,
        |batch| {
            let policy = PolicyKind::FastPf.build();
            let alloc = policy.allocate(batch, &mut Pcg64::new(3));
            let space = ConfigSpace::pruned(batch, 80, &mut Pcg64::new(4));
            if let Some(_imp) = find_pareto_improvement(&alloc, batch, &space, 5e-3) {
                return Err("PF allocation Pareto-dominated".into());
            }
            if let Some((coalition, _)) =
                find_blocking_coalition(&alloc, batch, &space, 5e-3)
            {
                return Err(format!("PF blocked by coalition {coalition:?}"));
            }
            Ok(())
        },
    );
}

/// OPTP weakly dominates every policy on total raw utility (it is the
/// utilitarian optimum) — a cross-policy sanity relation.
#[test]
fn optp_maximizes_total_utility() {
    check(
        20,
        |rng| random_sales_batch(2 + rng.index(4), rng),
        no_shrink,
        |batch| {
            let optp = PolicyKind::Optp.build();
            let u_opt: f64 = optp
                .allocate(batch, &mut Pcg64::new(5))
                .expected_utilities(batch)
                .iter()
                .sum();
            for kind in [PolicyKind::Static, PolicyKind::Mmf, PolicyKind::FastPf] {
                let policy = kind.build();
                let u: f64 = policy
                    .allocate(batch, &mut Pcg64::new(5))
                    .expected_utilities(batch)
                    .iter()
                    .sum();
                if u > u_opt + 1e-6 {
                    return Err(format!(
                        "{} total utility {u} > OPTP {u_opt}",
                        kind.name()
                    ));
                }
            }
            Ok(())
        },
    );
}

/// MMF maximizes the minimum scaled utility within its own config
/// space: no other tested policy achieves a strictly higher minimum.
#[test]
fn mmf_has_highest_minimum_rate() {
    check(
        15,
        |rng| random_sales_batch(2 + rng.index(3), rng),
        no_shrink,
        |batch| {
            let active = batch.active_tenants();
            if active.len() < 2 {
                return Ok(());
            }
            let min_rate = |kind: PolicyKind| -> f64 {
                let policy = kind.build();
                let v = policy
                    .allocate(batch, &mut Pcg64::new(6))
                    .expected_scaled_utilities(batch);
                active.iter().map(|&i| v[i]).fold(f64::INFINITY, f64::min)
            };
            let mmf = min_rate(PolicyKind::Mmf);
            for kind in [PolicyKind::Static, PolicyKind::Optp] {
                let other = min_rate(kind);
                if other > mmf + 0.02 {
                    return Err(format!(
                        "{} min rate {other} > MMF {mmf}",
                        kind.name()
                    ));
                }
            }
            Ok(())
        },
    );
}
