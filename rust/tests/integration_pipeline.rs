//! Integration tests over the full pipeline: workload generation →
//! batched coordinator → policy → cache → simulated execution →
//! metrics. These check cross-module invariants no unit test sees.

use robus::alloc::PolicyKind;
use robus::coordinator::loop_::{CommonConfig, CoordinatorConfig, RunResult};
use robus::domain::tenant::TenantSet;
use robus::session::Session;
use robus::sim::cluster::ClusterConfig;
use robus::sim::engine::SimEngine;
use robus::workload::generator::WorkloadGenerator;
use robus::workload::spec::{AccessSpec, TenantSpec, WindowSpec};
use robus::workload::universe::Universe;

fn run(kind: PolicyKind, universe: &Universe, specs: Vec<TenantSpec>, batches: usize, seed: u64) -> RunResult {
    let tenants = TenantSet::equal(specs.len());
    let engine = SimEngine::new(ClusterConfig::default());
    let config = CoordinatorConfig {
        common: CommonConfig {
            batch_secs: 40.0,
            seed,
            ..CommonConfig::default()
        },
        n_batches: batches,
    };
    let mut gen = WorkloadGenerator::new(specs, universe, seed);
    let policy = kind.build();
    Session::replay(universe, tenants, engine)
        .config(config)
        .run(&mut gen, policy.as_ref())
}

fn sales_specs(n: usize) -> Vec<TenantSpec> {
    (0..n)
        .map(|i| {
            TenantSpec::new(AccessSpec::g(1 + i % 4), 15.0)
                .with_window(WindowSpec::default())
        })
        .collect()
}

/// Every generated query appears exactly once in the outcomes, with
/// causally consistent timestamps.
#[test]
fn query_conservation_and_causality() {
    let universe = Universe::sales_only();
    for kind in [PolicyKind::Static, PolicyKind::FastPf, PolicyKind::Optp] {
        let r = run(kind, &universe, sales_specs(3), 8, 21);
        let mut ids: Vec<u64> = r.outcomes.iter().map(|o| o.id.0).collect();
        let n = ids.len();
        ids.sort_unstable();
        ids.dedup();
        assert_eq!(ids.len(), n, "{}: duplicate query outcomes", kind.name());
        let batch_total: usize = r.batches.iter().map(|b| b.n_queries).sum();
        assert_eq!(batch_total, n, "{}: lost queries", kind.name());
        for o in &r.outcomes {
            assert!(o.start >= o.arrival - 1e-9, "started before arrival");
            assert!(o.finish >= o.start, "finished before start");
        }
        // Batches execute in order; execution starts after window close.
        for b in &r.batches {
            assert!(b.exec_start >= b.window_end - 1e-9);
            assert!(b.exec_end >= b.exec_start);
        }
        for w in r.batches.windows(2) {
            assert!(w[1].exec_start >= w[0].exec_end - 1e-9);
        }
    }
}

/// The cache never exceeds its budget in any batch, under any policy.
#[test]
fn cache_budget_never_exceeded() {
    let universe = Universe::mixed();
    let budget = ClusterConfig::default().cache_budget;
    let sizes: Vec<u64> = universe.views.iter().map(|v| v.cached_bytes).collect();
    let specs = vec![
        TenantSpec::new(AccessSpec::h1(), 15.0),
        TenantSpec::new(AccessSpec::g(1), 15.0),
    ];
    for kind in [PolicyKind::Static, PolicyKind::Mmf, PolicyKind::FastPf, PolicyKind::Optp] {
        let r = run(kind, &universe, specs.clone(), 6, 3);
        for b in &r.batches {
            let used: u64 = b.config.ones().map(|v| sizes[v]).sum();
            assert!(
                used <= budget,
                "{}: batch {} used {used} > budget {budget}",
                kind.name(),
                b.index
            );
        }
    }
}

/// Identical seeds produce bit-identical runs (full determinism).
#[test]
fn end_to_end_determinism() {
    let universe = Universe::sales_only();
    let a = run(PolicyKind::FastPf, &universe, sales_specs(2), 6, 77);
    let b = run(PolicyKind::FastPf, &universe, sales_specs(2), 6, 77);
    assert_eq!(a.outcomes.len(), b.outcomes.len());
    for (x, y) in a.outcomes.iter().zip(&b.outcomes) {
        assert_eq!(x.id, y.id);
        assert_eq!(x.finish, y.finish);
        assert_eq!(x.from_cache, y.from_cache);
    }
    for (x, y) in a.batches.iter().zip(&b.batches) {
        assert_eq!(x.config, y.config);
    }
}

/// A tenant that submits nothing must not break any policy.
#[test]
fn idle_tenant_is_harmless() {
    let universe = Universe::sales_only();
    // Tenant 1 has a huge inter-arrival time: often empty batches.
    let specs = vec![
        TenantSpec::new(AccessSpec::g(1), 10.0),
        TenantSpec::new(AccessSpec::g(2), 100_000.0),
    ];
    for kind in [PolicyKind::Mmf, PolicyKind::FastPf, PolicyKind::Rsd] {
        let r = run(kind, &universe, specs.clone(), 5, 13);
        assert!(!r.outcomes.is_empty());
    }
}

/// Zero-query workloads produce clean empty runs.
#[test]
fn empty_workload_run() {
    let universe = Universe::sales_only();
    let specs = vec![TenantSpec::new(AccessSpec::g(1), 1e9)];
    let r = run(PolicyKind::FastPf, &universe, specs, 4, 1);
    assert!(r.outcomes.is_empty());
    assert_eq!(r.batches.len(), 4);
    assert_eq!(r.hit_ratio(), 0.0);
}

/// Throughput accounting matches raw outcome counts.
#[test]
fn throughput_formula() {
    let universe = Universe::sales_only();
    let r = run(PolicyKind::Optp, &universe, sales_specs(2), 6, 5);
    let expect = r.outcomes.len() as f64 / (r.end_time / 60.0);
    assert!((r.throughput_per_min() - expect).abs() < 1e-9);
}
