//! The shard-runtime scale suite (DESIGN.md §2g): the persistent
//! worker pool at 64 shards, far past the old spawn-per-batch
//! executor's comfort zone, on the deterministic SimClock drivers.
//!
//! Pins the two contracts the runtime refactor must keep:
//!
//! 1. **Determinism at scale** — two same-config 64-shard runs are
//!    bit-identical on every simulated quantity (outcomes, sampled
//!    configurations, accountant multipliers, per-tenant attainment),
//!    with 1000 tenants multiplexing over a handful of pool workers.
//! 2. **Worker-count invariance** — `workers` = `Some(0)` (inline),
//!    `Some(n)` (pinned pool), and `None` (host-sized pool) are one
//!    semantics: the pool width only changes host-side scheduling,
//!    never what is simulated.

use robus::alloc::PolicyKind;
use robus::cluster::{ClusterResult, FederatedServeReport, FederationConfig, ServeFederationConfig};
use robus::coordinator::loop_::CommonConfig;
use robus::coordinator::ServeConfig;
use robus::domain::tenant::TenantSet;
use robus::experiments::runner::run_federated;
use robus::experiments::{ExperimentSetup, UniverseKind};
use robus::session::Session;
use robus::sim::{ClusterConfig, SimEngine};
use robus::workload::spec::{AccessSpec, TenantSpec};
use robus::workload::{AdmissionPolicy, Universe};

const SHARDS: usize = 64;
const TENANTS: usize = 1000;

/// 64 shards × 1000 tenants, two batches — enough arrivals that every
/// shard sees traffic, small enough for the tier-1 suite.
fn scale_setup() -> ExperimentSetup {
    ExperimentSetup {
        name: "scale-64x1k".to_string(),
        universe: UniverseKind::SalesOnly,
        tenant_specs: (0..TENANTS)
            .map(|i| TenantSpec::new(AccessSpec::g(1 + i % 4), 40.0))
            .collect(),
        weights: vec![1.0; TENANTS],
        batch_secs: 20.0,
        n_batches: 2,
        stateful_gamma: None,
        seed: 4242,
        warm_start: false,
        tiers: None,
    }
}

fn fed(workers: Option<usize>) -> FederationConfig {
    let mut f = FederationConfig::with_shards(SHARDS);
    f.workers = workers;
    f
}

fn run(workers: Option<usize>) -> ClusterResult {
    let policy = PolicyKind::FastPf.build();
    run_federated(&scale_setup(), &fed(workers), policy.as_ref())
}

/// Bitwise equality of every simulated quantity two federation runs
/// produce (host-time fields like solve seconds legitimately differ).
fn assert_cluster_identical(a: &ClusterResult, b: &ClusterResult) {
    assert_eq!(a.run.outcomes.len(), b.run.outcomes.len());
    for (x, y) in a.run.outcomes.iter().zip(&b.run.outcomes) {
        assert_eq!(x.id, y.id);
        assert_eq!(x.tenant, y.tenant);
        assert_eq!(x.start, y.start);
        assert_eq!(x.finish, y.finish);
        assert_eq!(x.from_cache, y.from_cache);
    }
    assert_eq!(a.per_shard.len(), b.per_shard.len());
    for (sa, sb) in a.per_shard.iter().zip(&b.per_shard) {
        assert_eq!(sa.batches.len(), sb.batches.len());
        for (x, y) in sa.batches.iter().zip(&sb.batches) {
            assert_eq!(x.config, y.config, "sampled configurations diverged");
            assert_eq!(x.n_queries, y.n_queries);
            assert_eq!(x.delta, y.delta);
        }
    }
    assert_eq!(a.records.len(), b.records.len());
    for (x, y) in a.records.iter().zip(&b.records) {
        assert_eq!(x.multipliers, y.multipliers, "accountant diverged");
        assert_eq!(x.tenant_attained, y.tenant_attained);
        assert_eq!(x.tenant_attainable, y.tenant_attainable);
        assert_eq!(x.live_shards, y.live_shards);
    }
    assert_eq!(a.replication_bytes, b.replication_bytes);
    assert_eq!(a.rebalance_churn_bytes, b.rebalance_churn_bytes);
}

#[test]
fn replay_64_shards_1k_tenants_is_deterministic() {
    let a = run(Some(4));
    let b = run(Some(4));
    assert_eq!(a.n_shards(), SHARDS);
    assert!(
        a.run.outcomes.len() > 500,
        "scale run too small to mean anything: {} outcomes",
        a.run.outcomes.len()
    );
    assert_cluster_identical(&a, &b);
}

#[test]
fn replay_64_shards_invariant_to_worker_count() {
    // Inline (no pool threads at all), a pinned narrow pool, and the
    // host-sized default must simulate the exact same federation.
    let inline = run(Some(0));
    let pooled = run(Some(4));
    let auto = run(None);
    assert_cluster_identical(&inline, &pooled);
    assert_cluster_identical(&inline, &auto);
}

fn serve_scale(workers: Option<usize>) -> FederatedServeReport {
    let cfg = ServeConfig {
        common: CommonConfig {
            batch_secs: 0.25,
            seed: 77,
            warm_start: true,
            ..CommonConfig::default()
        },
        duration_secs: 0.75,
        rate_per_sec: 4000.0,
        n_tenants: 256,
        queue_capacity: 8192,
        admission: AdmissionPolicy::Drop,
        verbose: false,
    };
    let mut fcfg = ServeFederationConfig::new(cfg, SHARDS);
    fcfg.workers = workers;
    let universe = Universe::sales_only();
    let tenants = TenantSet::equal(fcfg.serve.n_tenants);
    let engine = SimEngine::new(ClusterConfig::default());
    let policy = PolicyKind::FastPf.build();
    Session::serve_federated(&universe, &tenants, &engine, fcfg)
        .sim()
        .run(policy.as_ref())
}

#[test]
fn serving_64_shards_deterministic_and_invariant_to_worker_count() {
    let a = serve_scale(Some(3));
    let b = serve_scale(Some(3));
    let inline = serve_scale(Some(0));
    assert_eq!(a.live_shards_final(), SHARDS);
    assert!(a.serve.completed > 500, "completed={}", a.serve.completed);
    // Conservation through the lock-free router at 64 shards.
    assert_eq!(a.serve.completed, a.serve.admitted);
    for other in [&b, &inline] {
        assert_eq!(a.serve.completed, other.serve.completed);
        assert_eq!(a.serve.admitted, other.serve.admitted);
        assert_eq!(a.serve.batches, other.serve.batches);
        assert_eq!(a.serve.per_tenant_completed, other.serve.per_tenant_completed);
        assert_cluster_identical(&a.cluster, &other.cluster);
    }
}
