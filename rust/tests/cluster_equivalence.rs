//! The federation's correctness contract:
//!
//! 1. With `--shards 1` the sharded coordinator is **bit-identical** to
//!    the serial `Coordinator::run` baseline — same sampled
//!    configurations, cache transitions, query outcomes, and summary
//!    metrics — across the §5.3 experiment grid. The federation is a
//!    routing + accounting layer; one shard must degenerate to the
//!    single-node loop exactly.
//! 2. With `--shards 4` on the Zipf workload, the global fairness
//!    accountant keeps the per-tenant utility spread (max/min
//!    weight-normalized tenant speedup vs the STATIC baseline) within
//!    1.25× of the single-node PF run: sharding must not silently trade
//!    global fairness for scale.
//! 3. Sharding conserves the workload: every arrival executes exactly
//!    once somewhere in the federation, whatever the shard count.

use robus::alloc::PolicyKind;
use robus::cluster::{speedup_spread, FederationConfig, PlacementStrategy};
use robus::coordinator::loop_::RunResult;
use robus::experiments::runner::{run_federated, run_with_policies_serial};
use robus::experiments::setups::{self, ExperimentSetup};

fn fed(n_shards: usize) -> FederationConfig {
    FederationConfig::with_shards(n_shards)
}

/// Bit-identity of a 1-shard federation run against the serial
/// coordinator, for one setup × policy cell.
fn assert_shards1_identical(setup: &ExperimentSetup, kind: PolicyKind) {
    let serial_out = run_with_policies_serial(setup, &[kind.build()]);
    let serial = &serial_out.runs[0];
    let policy = kind.build();
    let cluster = run_federated(setup, &fed(1), policy.as_ref());
    let run = &cluster.run;

    assert_eq!(cluster.n_shards(), 1);
    assert_eq!(serial.policy, run.policy, "{}", setup.name);
    assert_eq!(serial.end_time, run.end_time, "{}/{}", setup.name, kind.name());
    assert_eq!(serial.outcomes.len(), run.outcomes.len());
    for (s, c) in serial.outcomes.iter().zip(&run.outcomes) {
        assert_eq!(s.id, c.id);
        assert_eq!(s.tenant, c.tenant);
        assert_eq!(s.arrival, c.arrival);
        assert_eq!(s.start, c.start);
        assert_eq!(s.finish, c.finish);
        assert_eq!(s.from_cache, c.from_cache);
    }
    assert_eq!(serial.batches.len(), run.batches.len());
    for (s, c) in serial.batches.iter().zip(&run.batches) {
        assert_eq!(s.index, c.index);
        assert_eq!(s.n_queries, c.n_queries);
        assert_eq!(s.config, c.config, "{}/{}", setup.name, kind.name());
        assert_eq!(s.cache_utilization, c.cache_utilization);
        assert_eq!(s.delta, c.delta, "{}/{}", setup.name, kind.name());
        assert_eq!(s.window_end, c.window_end);
        assert_eq!(s.exec_start, c.exec_start);
        assert_eq!(s.exec_end, c.exec_end);
    }
    // Derived metrics (throughput, utilities via speedups, miss rates)
    // follow from the identical outcomes/batches; spot-check the
    // summary surface.
    assert_eq!(serial.throughput_per_min(), run.throughput_per_min());
    assert_eq!(serial.hit_ratio(), run.hit_ratio());
    assert_eq!(serial.avg_cache_utilization(), run.avg_cache_utilization());
    // The federation layer must be inert at one shard.
    assert_eq!(cluster.replication_bytes, 0);
    assert_eq!(cluster.rebalance_churn_bytes, 0);
    assert!(cluster
        .records
        .iter()
        .all(|r| r.multipliers.iter().all(|&m| m == 1.0)));
}

#[test]
fn shards1_identical_sales_grid() {
    for setup in setups::data_sharing_sales() {
        let setup = setup.quick(3);
        for kind in [PolicyKind::Static, PolicyKind::Mmf, PolicyKind::FastPf, PolicyKind::Optp] {
            assert_shards1_identical(&setup, kind);
        }
    }
}

#[test]
fn shards1_identical_mixed_and_arrival_grid() {
    // The mixed universe exercises multi-view (TPC-H) query classes —
    // the spanning-query routing path — and the arrival sweeps vary the
    // batch pressure.
    assert_shards1_identical(&setups::data_sharing_mixed()[1].clone().quick(3), PolicyKind::FastPf);
    assert_shards1_identical(&setups::data_sharing_mixed()[3].clone().quick(3), PolicyKind::Optp);
    for setup in setups::arrival_rates() {
        assert_shards1_identical(&setup.quick(3), PolicyKind::FastPf);
    }
}

#[test]
fn shards1_identical_tenant_scaling_and_stateful() {
    for setup in setups::tenant_scaling() {
        assert_shards1_identical(&setup.quick(3), PolicyKind::Mmf);
    }
    // A stateful (γ=2) Figure 12 cell: each shard's mirror must feed
    // the boost identically to the single-node planner's.
    let (stateful, _gamma) = setups::batch_size_sweep()
        .into_iter()
        .find(|(s, g)| s.batch_secs == 20.0 && g.is_some())
        .expect("stateful 20s cell exists");
    assert_shards1_identical(&stateful.quick(4), PolicyKind::FastPf);
}

/// Whatever the shard count or placement, the federation executes
/// exactly the arrivals the single-node run does — sharding changes
/// *where* queries run, never *whether*.
#[test]
fn sharding_conserves_the_workload() {
    let setup = setups::data_sharing_sales()[2].clone().quick(4);
    let serial = run_with_policies_serial(&setup, &[PolicyKind::FastPf.build()]);
    let mut expect: Vec<u64> = serial.runs[0].outcomes.iter().map(|o| o.id.0).collect();
    expect.sort_unstable();
    for shards in [2usize, 3, 4] {
        for placement in [PlacementStrategy::Hash, PlacementStrategy::Pack] {
            let mut cfg = fed(shards);
            cfg.placement = placement;
            let policy = PolicyKind::FastPf.build();
            let result = run_federated(&setup, &cfg, policy.as_ref());
            let mut got: Vec<u64> = result.run.outcomes.iter().map(|o| o.id.0).collect();
            got.sort_unstable();
            assert_eq!(
                got, expect,
                "{shards} shards / {} lost or duplicated queries",
                placement.name()
            );
            // Shard outcome counts partition the total.
            let per_shard: usize = result.per_shard.iter().map(|r| r.outcomes.len()).sum();
            assert_eq!(per_shard, expect.len());
        }
    }
}

/// The acceptance bar: at 4 shards on the Zipf workload the global
/// per-tenant utility spread stays within 1.25× of the single-node PF
/// run's spread. (Both measured as max/min weight-normalized tenant
/// speedup against the same STATIC single-node baseline.)
#[test]
fn four_shards_fairness_spread_within_bound() {
    // Four g₁ Zipf tenants (Table 13 shape); 15 batches so per-tenant
    // mean speedups average over enough queries to be stable.
    let setup = setups::tenant_scaling()[1].clone().quick(15);
    let baseline = run_with_policies_serial(&setup, &[PolicyKind::Static.build()]);
    let single = run_with_policies_serial(&setup, &[PolicyKind::FastPf.build()]);
    let policy = PolicyKind::FastPf.build();
    let federated = run_federated(&setup, &fed(4), policy.as_ref());

    let spread_single = speedup_spread(&single.runs[0], &baseline.runs[0]);
    let spread_fed = federated.fairness_spread(&baseline.runs[0]);
    assert!(
        spread_single.is_finite() && spread_fed.is_finite(),
        "spreads must be finite: single={spread_single} fed={spread_fed}"
    );
    assert!(
        spread_fed <= spread_single * 1.25 + 1e-9,
        "4-shard spread {spread_fed:.3} exceeds 1.25x single-node {spread_single:.3}"
    );
    // The accountant actually engaged: multipliers were emitted from
    // batch 1 on (all-ones only if attainment stayed perfectly even).
    assert_eq!(federated.records.len(), setup.n_batches);
    assert!(federated
        .records
        .iter()
        .skip(1)
        .all(|r| r.multipliers.len() == 4));
}

/// Hot-view replication: with a low threshold on a head-heavy Zipf
/// workload, the top views get replicated, replica bytes are charged,
/// and the workload is still conserved.
#[test]
fn hot_view_replication_triggers_and_conserves() {
    let setup = setups::data_sharing_sales()[0].clone().quick(5);
    let mut cfg = fed(4);
    cfg.replicate_hot = Some(0.05);
    let policy = PolicyKind::FastPf.build();
    let result = run_federated(&setup, &cfg, policy.as_ref());
    assert!(
        result.replication_bytes > 0,
        "a 5% threshold on Zipf demand must replicate something"
    );
    assert!(result
        .records
        .iter()
        .any(|r| !r.replicated_views.is_empty()));
    let serial = run_with_policies_serial(&setup, &[PolicyKind::FastPf.build()]);
    assert_eq!(result.run.outcomes.len(), serial.runs[0].outcomes.len());
}

/// Demand-driven rebalance: re-homing fires on schedule and reports
/// previewed churn without disturbing workload conservation.
#[test]
fn rebalance_fires_on_schedule() {
    let setup = setups::data_sharing_sales()[3].clone().quick(6);
    let mut cfg = fed(4);
    cfg.rebalance_every = Some(2);
    let policy = PolicyKind::FastPf.build();
    let result = run_federated(&setup, &cfg, policy.as_ref());
    // Batches 2 and 4 are rebalance points; at least one should re-home
    // (hash placement vs demand-packed placement differ on this skew).
    assert!(
        result.records.iter().any(|r| r.rebalanced),
        "no rebalance fired in 6 batches at every-2 cadence"
    );
    let serial = run_with_policies_serial(&setup, &[PolicyKind::FastPf.build()]);
    assert_eq!(result.run.outcomes.len(), serial.runs[0].outcomes.len());
}

/// Scaling smoke (not a wall-clock assertion — CI hosts vary): the
/// 4-shard run's slowest per-batch shard solve should not exceed the
/// single-node solve of the same batch, since each shard solves a
/// subset of the classes. Guarded loosely to stay robust.
#[test]
fn shard_solves_are_subproblems() {
    let setup = setups::data_sharing_sales()[1].clone().quick(5);
    let single = run_with_policies_serial(&setup, &[PolicyKind::FastPf.build()]);
    let policy = PolicyKind::FastPf.build();
    let federated = run_federated(&setup, &fed(4), policy.as_ref());
    let single_total: f64 = single.runs[0].batches.iter().map(|b| b.solve_secs).sum();
    // Critical path = slowest shard per batch (they run concurrently).
    let fed_critical: f64 = federated.run.batches.iter().map(|b| b.solve_secs).sum();
    // Very generous bound (host timing under parallel test threads is
    // noisy); the point is gross sub-linearity, not an exact ratio.
    assert!(
        fed_critical <= single_total * 3.0 + 0.25,
        "4-shard critical-path solve {fed_critical:.4}s vs single {single_total:.4}s"
    );
}

/// The merged federation RunResult is internally consistent.
#[test]
fn merged_run_shape() {
    let setup = setups::data_sharing_sales()[1].clone().quick(4);
    let policy = PolicyKind::FastPf.build();
    let result = run_federated(&setup, &fed(3), policy.as_ref());
    let run: &RunResult = &result.run;
    assert_eq!(run.batches.len(), setup.n_batches);
    let batch_total: usize = run.batches.iter().map(|b| b.n_queries).sum();
    assert_eq!(batch_total, run.outcomes.len());
    // Outcomes sorted by id, no duplicates.
    for w in run.outcomes.windows(2) {
        assert!(w[0].id < w[1].id);
    }
    // Union config and per-shard summaries agree with the shard count.
    assert_eq!(result.shard_summaries().len(), 3);
    assert!((0.0..=1.0 + 1e-9).contains(&run.hit_ratio()));
}
