//! Conversion pins for the Session API migration (ISSUE 10): every
//! legacy entry point that became a thin `#[deprecated]` delegate must
//! produce results **bit-identical** to the Session construction the
//! deprecation note names. This is the contract that lets callers
//! migrate mechanically: old call → new call, nothing re-tuned.
//!
//! The SimClock drivers (replay serial/pipelined, sim-serve, federated
//! replay, federated sim-serve) are pinned exactly, simulated quantity
//! by simulated quantity. The real-clock drivers (`serve`,
//! `serve_with`, `serve_federated`, `serve_federated_with`) are
//! nondeterministic by nature — batch cuts land on a host timer — so
//! bit-identity is not defined for them; they are pinned on their
//! conservation ledger and config plumbing instead.

#![allow(deprecated)]

use robus::alloc::{Policy, PolicyKind};
use robus::cluster::{
    serve_federated, serve_federated_sim, serve_federated_sim_with, serve_federated_with,
    FederationConfig, ServeFederationConfig, ShardedCoordinator,
};
use robus::coordinator::loop_::{CommonConfig, Coordinator, CoordinatorConfig, RunResult};
use robus::coordinator::service::{serve, serve_sim, serve_sim_with, serve_with, AdmissionPolicy};
use robus::coordinator::{ServeConfig, ServeReport};
use robus::domain::tenant::TenantSet;
use robus::session::Session;
use robus::sim::{ClusterConfig, SimEngine};
use robus::telemetry::Telemetry;
use robus::workload::generator::WorkloadGenerator;
use robus::workload::spec::{AccessSpec, TenantSpec};
use robus::workload::Universe;

fn specs(n: usize) -> Vec<TenantSpec> {
    (0..n)
        .map(|i| TenantSpec::new(AccessSpec::g(1 + i % 4), 20.0))
        .collect()
}

fn replay_cfg() -> CoordinatorConfig {
    CoordinatorConfig {
        common: CommonConfig {
            batch_secs: 40.0,
            seed: 11,
            ..CommonConfig::default()
        },
        n_batches: 5,
    }
}

fn serve_cfg() -> ServeConfig {
    ServeConfig {
        common: CommonConfig {
            batch_secs: 0.25,
            seed: 19,
            warm_start: true,
            ..CommonConfig::default()
        },
        duration_secs: 1.5,
        rate_per_sec: 400.0,
        n_tenants: 3,
        queue_capacity: 16_384,
        admission: AdmissionPolicy::Drop,
        verbose: false,
    }
}

fn assert_runs_identical(label: &str, a: &RunResult, b: &RunResult) {
    assert!(!a.outcomes.is_empty(), "{label}: degenerate run proves nothing");
    assert_eq!(a.outcomes.len(), b.outcomes.len(), "{label}");
    for (x, y) in a.outcomes.iter().zip(&b.outcomes) {
        assert_eq!(x.id, y.id, "{label}");
        assert_eq!(x.tenant, y.tenant, "{label}");
        assert_eq!(x.arrival, y.arrival, "{label}");
        assert_eq!(x.start, y.start, "{label}");
        assert_eq!(x.finish, y.finish, "{label}");
        assert_eq!(x.from_cache, y.from_cache, "{label}");
    }
    assert_eq!(a.batches.len(), b.batches.len(), "{label}");
    for (x, y) in a.batches.iter().zip(&b.batches) {
        assert_eq!(x.config, y.config, "{label}");
        assert_eq!(x.ssd, y.ssd, "{label}");
        assert_eq!(x.delta, y.delta, "{label}");
        assert_eq!(x.cache_utilization, y.cache_utilization, "{label}");
        assert_eq!(x.exec_start, y.exec_start, "{label}");
        assert_eq!(x.exec_end, y.exec_end, "{label}");
    }
    assert_eq!(a.end_time, b.end_time, "{label}");
}

/// Simulated (host-independent) fields of a serve report.
fn assert_reports_identical(label: &str, a: &ServeReport, b: &ServeReport) {
    assert!(a.completed > 0, "{label}: nothing served proves nothing");
    assert_eq!(a.batches, b.batches, "{label}");
    assert_eq!(a.admitted, b.admitted, "{label}");
    assert_eq!(a.rejected, b.rejected, "{label}");
    assert_eq!(a.completed, b.completed, "{label}");
    assert_eq!(a.per_tenant_completed, b.per_tenant_completed, "{label}");
    assert_eq!(a.max_batch, b.max_batch, "{label}");
    assert_eq!(a.peak_queue_depth, b.peak_queue_depth, "{label}");
    assert_eq!(a.hit_ratio, b.hit_ratio, "{label}");
    assert_eq!(a.avg_cache_utilization, b.avg_cache_utilization, "{label}");
    assert_eq!(a.throughput_fairness, b.throughput_fairness, "{label}");
}

/// `Coordinator::run` / `run_with` → `Session::replay(..).run(..)`.
#[test]
fn replay_serial_delegates_pin() {
    let universe = Universe::sales_only();
    let engine = SimEngine::new(ClusterConfig::default());
    let policy: Box<dyn Policy> = PolicyKind::FastPf.build();
    let coordinator =
        Coordinator::new(&universe, TenantSet::equal(3), engine, replay_cfg());

    let mut gen = WorkloadGenerator::new(specs(3), &universe, 11);
    let old = coordinator.run(&mut gen, policy.as_ref());

    let tel = Telemetry::off();
    let mut gen = WorkloadGenerator::new(specs(3), &universe, 11);
    let old_tel = coordinator.run_with(&mut gen, policy.as_ref(), &tel);

    let mut gen = WorkloadGenerator::new(specs(3), &universe, 11);
    let new = Session::replay(
        &universe,
        TenantSet::equal(3),
        SimEngine::new(ClusterConfig::default()),
    )
    .config(replay_cfg())
    .run(&mut gen, policy.as_ref());

    let mut gen = WorkloadGenerator::new(specs(3), &universe, 11);
    let new_tel = Session::replay(
        &universe,
        TenantSet::equal(3),
        SimEngine::new(ClusterConfig::default()),
    )
    .config(replay_cfg())
    .telemetry(&tel)
    .run(&mut gen, policy.as_ref());

    assert_runs_identical("run → Session::replay.run", &old, &new);
    assert_runs_identical("run_with → Session::replay.telemetry.run", &old_tel, &new_tel);
}

/// `Coordinator::run_pipelined` / `run_pipelined_with` →
/// `Session::replay(..).pipelined(depth).run(..)`.
#[test]
fn replay_pipelined_delegates_pin() {
    let universe = Universe::sales_only();
    let policy: Box<dyn Policy> = PolicyKind::FastPf.build();
    let coordinator = Coordinator::new(
        &universe,
        TenantSet::equal(3),
        SimEngine::new(ClusterConfig::default()),
        replay_cfg(),
    );

    let mut gen = WorkloadGenerator::new(specs(3), &universe, 11);
    let old = coordinator.run_pipelined(&mut gen, policy.as_ref(), 2);

    let tel = Telemetry::off();
    let mut gen = WorkloadGenerator::new(specs(3), &universe, 11);
    let old_tel = coordinator.run_pipelined_with(&mut gen, policy.as_ref(), 2, &tel);

    let mut gen = WorkloadGenerator::new(specs(3), &universe, 11);
    let new = Session::replay(
        &universe,
        TenantSet::equal(3),
        SimEngine::new(ClusterConfig::default()),
    )
    .config(replay_cfg())
    .pipelined(2)
    .run(&mut gen, policy.as_ref());

    let mut gen = WorkloadGenerator::new(specs(3), &universe, 11);
    let new_tel = Session::replay(
        &universe,
        TenantSet::equal(3),
        SimEngine::new(ClusterConfig::default()),
    )
    .config(replay_cfg())
    .pipelined(2)
    .telemetry(&tel)
    .run(&mut gen, policy.as_ref());

    assert_runs_identical("run_pipelined → Session.pipelined.run", &old, &new);
    assert_runs_identical(
        "run_pipelined_with → Session.pipelined.telemetry.run",
        &old_tel,
        &new_tel,
    );
}

/// `serve_sim` / `serve_sim_with` → `Session::serve(..).sim().run(..)`.
#[test]
fn serve_sim_delegates_pin() {
    let universe = Universe::sales_only();
    let tenants = TenantSet::equal(3);
    let engine = SimEngine::new(ClusterConfig::default());
    let policy: Box<dyn Policy> = PolicyKind::FastPf.build();
    let cfg = serve_cfg();

    let (old_report, old_run) = serve_sim(&universe, &tenants, &engine, policy.as_ref(), &cfg);
    let tel = Telemetry::off();
    let (old_report_tel, old_run_tel) =
        serve_sim_with(&universe, &tenants, &engine, policy.as_ref(), &cfg, &tel);

    let (new_report, new_run) = Session::serve(&universe, &tenants, &engine)
        .config(cfg.clone())
        .sim()
        .run(policy.as_ref());
    let (new_report_tel, new_run_tel) = Session::serve(&universe, &tenants, &engine)
        .config(cfg.clone())
        .telemetry(&tel)
        .sim()
        .run(policy.as_ref());

    assert_runs_identical("serve_sim → Session.serve.sim.run", &old_run, &new_run);
    assert_reports_identical("serve_sim report", &old_report, &new_report);
    assert_runs_identical("serve_sim_with", &old_run_tel, &new_run_tel);
    assert_reports_identical("serve_sim_with report", &old_report_tel, &new_report_tel);
}

/// `ShardedCoordinator::run` / `run_with` →
/// `Session::federated(..).run(..)`.
#[test]
fn federated_replay_delegates_pin() {
    let universe = Universe::sales_only();
    let policy: Box<dyn Policy> = PolicyKind::FastPf.build();
    let fed = FederationConfig::with_shards(2);

    let sharded = ShardedCoordinator::new(
        &universe,
        TenantSet::equal(3),
        SimEngine::new(ClusterConfig::default()),
        replay_cfg(),
        fed.clone(),
    );
    let mut gen = WorkloadGenerator::new(specs(3), &universe, 11);
    let old = sharded.run(&mut gen, policy.as_ref());
    let tel = Telemetry::off();
    let mut gen = WorkloadGenerator::new(specs(3), &universe, 11);
    let old_tel = sharded.run_with(&mut gen, policy.as_ref(), &tel);

    let mut gen = WorkloadGenerator::new(specs(3), &universe, 11);
    let new = Session::federated(
        &universe,
        TenantSet::equal(3),
        SimEngine::new(ClusterConfig::default()),
    )
    .config(replay_cfg())
    .federation(fed.clone())
    .run(&mut gen, policy.as_ref());

    let mut gen = WorkloadGenerator::new(specs(3), &universe, 11);
    let new_tel = Session::federated(
        &universe,
        TenantSet::equal(3),
        SimEngine::new(ClusterConfig::default()),
    )
    .config(replay_cfg())
    .federation(fed)
    .telemetry(&tel)
    .run(&mut gen, policy.as_ref());

    assert_runs_identical("ShardedCoordinator::run → Session.federated.run", &old.run, &new.run);
    assert_eq!(old.per_shard.len(), new.per_shard.len());
    assert_runs_identical("ShardedCoordinator::run_with", &old_tel.run, &new_tel.run);
}

/// `serve_federated_sim` / `serve_federated_sim_with` →
/// `Session::serve_federated(..).sim().run(..)`.
#[test]
fn serve_federated_sim_delegates_pin() {
    let universe = Universe::sales_only();
    let tenants = TenantSet::equal(3);
    let engine = SimEngine::new(ClusterConfig::default());
    let policy: Box<dyn Policy> = PolicyKind::FastPf.build();
    let fcfg = ServeFederationConfig::new(serve_cfg(), 2);

    let old = serve_federated_sim(&universe, &tenants, &engine, policy.as_ref(), &fcfg);
    let tel = Telemetry::off();
    let old_tel =
        serve_federated_sim_with(&universe, &tenants, &engine, policy.as_ref(), &fcfg, &tel);

    let new = Session::serve_federated(&universe, &tenants, &engine, fcfg.clone())
        .sim()
        .run(policy.as_ref());
    let new_tel = Session::serve_federated(&universe, &tenants, &engine, fcfg)
        .telemetry(&tel)
        .sim()
        .run(policy.as_ref());

    assert_runs_identical("serve_federated_sim", &old.cluster.run, &new.cluster.run);
    assert_reports_identical("serve_federated_sim report", &old.serve, &new.serve);
    assert_eq!(old.initial_shards, new.initial_shards);
    assert_runs_identical("serve_federated_sim_with", &old_tel.cluster.run, &new_tel.cluster.run);
    assert_reports_identical("serve_federated_sim_with report", &old_tel.serve, &new_tel.serve);
}

/// Real-clock `serve` / `serve_with` → `Session::serve(..).run(..)`.
/// Batch boundaries land on a host timer, so these are pinned on the
/// conservation ledger and plumbing, not bits.
#[test]
fn serve_real_clock_delegates_pin() {
    let universe = Universe::sales_only();
    let tenants = TenantSet::equal(2);
    let engine = SimEngine::new(ClusterConfig::default());
    let policy: Box<dyn Policy> = PolicyKind::FastPf.build();
    let cfg = ServeConfig {
        duration_secs: 0.4,
        rate_per_sec: 200.0,
        n_tenants: 2,
        ..serve_cfg()
    };

    let tel = Telemetry::off();
    let old = serve(&universe, &tenants, &engine, policy.as_ref(), &cfg);
    let old_tel = serve_with(&universe, &tenants, &engine, policy.as_ref(), &cfg, &tel);
    let new = Session::serve(&universe, &tenants, &engine)
        .config(cfg.clone())
        .run(policy.as_ref());
    let new_tel = Session::serve(&universe, &tenants, &engine)
        .config(cfg)
        .telemetry(&tel)
        .run(policy.as_ref());

    for (label, r) in [
        ("serve", &old),
        ("serve_with", &old_tel),
        ("Session.serve.run", &new),
        ("Session.serve.telemetry.run", &new_tel),
    ] {
        assert_eq!(r.completed, r.admitted, "{label}: drained ledger conserves");
        assert_eq!(r.per_tenant_completed.len(), 2, "{label}");
        assert_eq!(
            r.per_tenant_completed.iter().sum::<u64>(),
            r.completed,
            "{label}"
        );
    }
}

/// Real-clock `serve_federated` / `serve_federated_with` →
/// `Session::serve_federated(..).run(..)`: conservation + plumbing.
#[test]
fn serve_federated_real_clock_delegates_pin() {
    let universe = Universe::sales_only();
    let tenants = TenantSet::equal(2);
    let engine = SimEngine::new(ClusterConfig::default());
    let policy: Box<dyn Policy> = PolicyKind::FastPf.build();
    let fcfg = ServeFederationConfig::new(
        ServeConfig {
            duration_secs: 0.4,
            rate_per_sec: 200.0,
            n_tenants: 2,
            ..serve_cfg()
        },
        2,
    );

    let tel = Telemetry::off();
    let old = serve_federated(&universe, &tenants, &engine, policy.as_ref(), &fcfg);
    let old_tel =
        serve_federated_with(&universe, &tenants, &engine, policy.as_ref(), &fcfg, &tel);
    let new = Session::serve_federated(&universe, &tenants, &engine, fcfg.clone())
        .run(policy.as_ref());
    let new_tel = Session::serve_federated(&universe, &tenants, &engine, fcfg)
        .telemetry(&tel)
        .run(policy.as_ref());

    for (label, r) in [
        ("serve_federated", &old),
        ("serve_federated_with", &old_tel),
        ("Session.serve_federated.run", &new),
        ("Session.serve_federated.telemetry.run", &new_tel),
    ] {
        assert_eq!(
            r.serve.completed, r.serve.admitted,
            "{label}: drained ledger conserves"
        );
        assert_eq!(r.initial_shards, 2, "{label}");
    }
}
