//! Warm-start equivalence (PR 6): carried solver state is a pure
//! performance optimization — quality must match the cold path within ε
//! across the §5.3 grid, `--warm-start off` must be bit-identical to
//! the historical replay, and a 1-shard warm federation must be
//! bit-identical to the warm serial coordinator (the same equivalence
//! ladder every federation feature is held to).

use robus::alloc::PolicyKind;
use robus::cluster::FederationConfig;
use robus::coordinator::loop_::RunResult;
use robus::experiments::runner::{run_federated, run_with_policies_serial};
use robus::experiments::setups::{self, ExperimentSetup};

fn serial_run(setup: &ExperimentSetup, kind: PolicyKind) -> RunResult {
    run_with_policies_serial(setup, &[kind.build()])
        .runs
        .into_iter()
        .next()
        .unwrap()
}

fn assert_bit_identical(a: &RunResult, b: &RunResult, what: &str) {
    assert_eq!(a.end_time, b.end_time, "{what}");
    assert_eq!(a.outcomes.len(), b.outcomes.len(), "{what}");
    for (s, c) in a.outcomes.iter().zip(&b.outcomes) {
        assert_eq!(s.id, c.id, "{what}");
        assert_eq!(s.start, c.start, "{what}");
        assert_eq!(s.finish, c.finish, "{what}");
        assert_eq!(s.from_cache, c.from_cache, "{what}");
    }
    assert_eq!(a.batches.len(), b.batches.len(), "{what}");
    for (s, c) in a.batches.iter().zip(&b.batches) {
        assert_eq!(s.config, c.config, "{what} batch {}", s.index);
        assert_eq!(s.delta, c.delta, "{what} batch {}", s.index);
    }
}

/// Quality equivalence over the §5.3 Sales grid: a warm FASTPF run must
/// land within ε of the cold run on hit ratio, cache utilization, and
/// the Jain fairness index (warm starts change *when* the solver
/// converges, not *where*, up to re-pruning approximation).
#[test]
fn warm_matches_cold_quality_across_sales_grid() {
    for setup in setups::data_sharing_sales() {
        let setup = setup.quick(8);
        let cold = run_with_policies_serial(
            &setup,
            &[PolicyKind::Static.build(), PolicyKind::FastPf.build()],
        );
        let warm = run_with_policies_serial(
            &setup.clone().with_warm_start(true),
            &[PolicyKind::Static.build(), PolicyKind::FastPf.build()],
        );
        // Identical workload either way: the generator never sees the
        // warm flag.
        assert_eq!(
            cold.runs[1].outcomes.len(),
            warm.runs[1].outcomes.len(),
            "{}",
            setup.name
        );
        let c = &cold.summaries[1];
        let w = &warm.summaries[1];
        assert!(
            (c.hit_ratio - w.hit_ratio).abs() < 0.15,
            "{}: hit ratio cold {:.3} vs warm {:.3}",
            setup.name,
            c.hit_ratio,
            w.hit_ratio
        );
        assert!(
            (c.avg_cache_utilization - w.avg_cache_utilization).abs() < 0.15,
            "{}: utilization cold {:.3} vs warm {:.3}",
            setup.name,
            c.avg_cache_utilization,
            w.avg_cache_utilization
        );
        assert!(
            (c.fairness_index - w.fairness_index).abs() < 0.25,
            "{}: fairness cold {:.3} vs warm {:.3}",
            setup.name,
            c.fairness_index,
            w.fairness_index
        );
    }
}

/// Same ε-equivalence for the MW solvers (duals/weights seeding plus
/// early exit) on one grid cell each — the unit tests pin per-solve
/// behavior; this pins the end-to-end run.
#[test]
fn warm_mw_solvers_keep_quality_on_g2() {
    let setup = setups::data_sharing_sales()[1].clone().quick(6);
    for kind in [PolicyKind::Mmf, PolicyKind::MmfMw] {
        let cold = serial_run(&setup, kind);
        let warm = serial_run(&setup.clone().with_warm_start(true), kind);
        assert_eq!(cold.outcomes.len(), warm.outcomes.len(), "{}", kind.name());
        let hr = |r: &RunResult| {
            let hits = r.outcomes.iter().filter(|o| o.from_cache).count();
            hits as f64 / r.outcomes.len().max(1) as f64
        };
        assert!(
            (hr(&cold) - hr(&warm)).abs() < 0.2,
            "{}: hit ratio cold {:.3} vs warm {:.3}",
            kind.name(),
            hr(&cold),
            hr(&warm)
        );
    }
}

/// `--warm-start off` (the default for replay) is the historical code
/// path: two cold runs are bit-identical, and an explicit `false` is
/// bit-identical to the default.
#[test]
fn warm_off_is_bit_identical_to_default_replay() {
    let setup = setups::data_sharing_sales()[1].clone().quick(6);
    let a = serial_run(&setup, PolicyKind::FastPf);
    let b = serial_run(&setup.clone().with_warm_start(false), PolicyKind::FastPf);
    assert_bit_identical(&a, &b, "cold default vs explicit warm_start=false");
}

/// The PR-3 ladder, warm edition: a 1-shard federation with per-shard
/// warm state must stay bit-identical to the warm serial coordinator
/// (shard 0 uses the serial planner's RNG stream, and the shard's
/// `WarmState` sees the same batch sequence as the planner's).
#[test]
fn one_shard_warm_federation_matches_warm_serial() {
    let setup = setups::data_sharing_sales()[1]
        .clone()
        .quick(6)
        .with_warm_start(true);
    let serial = serial_run(&setup, PolicyKind::FastPf);
    let fed = FederationConfig {
        n_shards: 1,
        warm_start: true,
        ..FederationConfig::default()
    };
    let policy = PolicyKind::FastPf.build();
    let cluster = run_federated(&setup, &fed, policy.as_ref());
    assert_bit_identical(&serial, &cluster.run, "warm serial vs warm 1-shard federation");
}
