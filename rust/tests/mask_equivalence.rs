//! Mask-path equivalence: the `ConfigMask`/`BatchIndex` fast paths must
//! be *bit-exact* with the legacy `Vec<bool>` per-view semantics for
//! `utilities()`, `scaled_utilities()`, `tenant_utility()`, `size_of()`,
//! and the WELFARE oracle (template vs. freshly built instance) — on the
//! paper's canonical Tables 2–5 and on randomized instances (seeded
//! `Pcg64`), including multi-view query classes the matrix instances
//! don't exercise.
//!
//! The legacy reference below is a verbatim reimplementation of the
//! pre-refactor evaluation code: per-class `views.iter().all(|&v|
//! sel[v])` walks, `u / u_star` scaling, per-view size filters.

use robus::alloc::instances::{matrix_instance, table2, table3, table4, table5};
use robus::alloc::ConfigMask;
use robus::domain::dataset::DatasetCatalog;
use robus::domain::query::{Query, QueryId};
use robus::domain::tenant::{TenantId, TenantSet};
use robus::domain::utility::BatchUtilities;
use robus::domain::view::{ViewCatalog, ViewId, ViewKind};
use robus::util::proptest::{check, no_shrink};
use robus::util::rng::Pcg64;

// ---- the legacy Vec<bool> reference semantics --------------------------

fn legacy_utilities(b: &BatchUtilities, sel: &[bool]) -> Vec<f64> {
    let mut u = vec![0.0; b.n_tenants];
    for c in &b.classes {
        if c.views.iter().all(|&v| sel[v]) {
            u[c.tenant] += c.utility;
        }
    }
    u
}

fn legacy_scaled_utilities(b: &BatchUtilities, sel: &[bool]) -> Vec<f64> {
    legacy_utilities(b, sel)
        .iter()
        .enumerate()
        .map(|(i, &u)| if b.u_star[i] > 0.0 { u / b.u_star[i] } else { 1.0 })
        .collect()
}

fn legacy_tenant_utility(b: &BatchUtilities, tenant: usize, sel: &[bool]) -> f64 {
    b.classes
        .iter()
        .filter(|c| c.tenant == tenant && c.views.iter().all(|&v| sel[v]))
        .map(|c| c.utility)
        .sum()
}

fn legacy_size_of(b: &BatchUtilities, sel: &[bool]) -> f64 {
    b.view_sizes
        .iter()
        .zip(sel)
        .filter(|(_, &s)| s)
        .map(|(sz, _)| *sz)
        .sum()
}

/// Every subset of up to `n_views` views when small, else `samples`
/// random subsets.
fn selections(b: &BatchUtilities, rng: &mut Pcg64, samples: usize) -> Vec<Vec<bool>> {
    let nv = b.n_views();
    if nv <= 10 {
        (0u32..(1 << nv))
            .map(|mask| (0..nv).map(|v| mask & (1 << v) != 0).collect())
            .collect()
    } else {
        (0..samples)
            .map(|_| (0..nv).map(|_| rng.below(2) == 1).collect())
            .collect()
    }
}

fn assert_batch_equivalence(b: &BatchUtilities, rng: &mut Pcg64) {
    for sel in selections(b, rng, 64) {
        let mask = ConfigMask::from_bools(&sel);
        assert_eq!(
            b.utilities(&mask),
            legacy_utilities(b, &sel),
            "utilities diverge on {sel:?}"
        );
        assert_eq!(
            b.scaled_utilities(&mask),
            legacy_scaled_utilities(b, &sel),
            "scaled_utilities diverge on {sel:?}"
        );
        assert_eq!(
            b.size_of(&mask),
            legacy_size_of(b, &sel),
            "size_of diverges on {sel:?}"
        );
        for t in 0..b.n_tenants {
            assert_eq!(
                b.tenant_utility(t, &mask),
                legacy_tenant_utility(b, t, &sel),
                "tenant_utility({t}) diverges on {sel:?}"
            );
        }
    }
}

fn assert_welfare_equivalence(b: &BatchUtilities, rng: &mut Pcg64) {
    let mut template = b.welfare_template();
    for _ in 0..8 {
        let w = rng.unit_weight_vector(b.n_tenants);
        let via_template = template.solve(&w);
        let via_problem = b.welfare_problem(&w).solve_exact();
        assert_eq!(via_template.selected, via_problem.selected, "w={w:?}");
        assert_eq!(via_template.value, via_problem.value, "w={w:?}");
    }
}

// ---- canonical instances ----------------------------------------------

#[test]
fn tables_2_to_5_bit_exact() {
    let mut rng = Pcg64::new(2024);
    for b in [table2(), table3(), table4(4), table4(6), table5()] {
        assert_batch_equivalence(&b, &mut rng);
        assert_welfare_equivalence(&b, &mut rng);
    }
}

// ---- randomized instances ---------------------------------------------

/// Random single-view utility matrices (the Tables 2–5 shape).
#[test]
fn random_matrix_instances_bit_exact() {
    check(
        40,
        |rng| {
            let n_tenants = 1 + rng.index(5);
            let n_views = 1 + rng.index(8);
            let rows: Vec<Vec<u64>> = (0..n_tenants)
                .map(|_| (0..n_views).map(|_| rng.below(6)).collect())
                .collect();
            let budget = 1.0 + rng.index(n_views) as f64;
            (rows, budget)
        },
        no_shrink,
        |(rows, budget)| {
            let refs: Vec<&[u64]> = rows.iter().map(|r| r.as_slice()).collect();
            let b = matrix_instance(&refs, *budget);
            let mut rng = Pcg64::new(7);
            assert_batch_equivalence(&b, &mut rng);
            assert_welfare_equivalence(&b, &mut rng);
            Ok(())
        },
    );
}

/// Random instances with multi-view query classes (all-or-nothing sets
/// spanning several views) and non-unit view sizes.
#[test]
fn random_multiview_instances_bit_exact() {
    check(
        30,
        |rng| {
            let n_tenants = 1 + rng.index(4);
            let n_views = 2 + rng.index(12);
            let n_queries = 1 + rng.index(20);
            let sizes: Vec<u64> = (0..n_views).map(|_| 50 + rng.below(200)).collect();
            let queries: Vec<(usize, Vec<usize>, u64)> = (0..n_queries)
                .map(|_| {
                    let tenant = rng.index(n_tenants);
                    let k = 1 + rng.index(3.min(n_views));
                    let mut views: Vec<usize> = (0..n_views).collect();
                    rng.shuffle(&mut views);
                    views.truncate(k);
                    (tenant, views, 1 + rng.below(100))
                })
                .collect();
            let total: u64 = sizes.iter().sum();
            let budget = (total as f64) * (0.2 + 0.6 * rng.next_f64());
            (n_tenants, sizes, queries, budget)
        },
        no_shrink,
        |(n_tenants, sizes, queries, budget)| {
            let mut ds = DatasetCatalog::new();
            let mut vc = ViewCatalog::new();
            for (v, &sz) in sizes.iter().enumerate() {
                let d = ds.add(&format!("d{v}"), sz);
                vc.add(&format!("v{v}"), d, ViewKind::BaseTable, sz, sz);
            }
            let ts = TenantSet::equal(*n_tenants);
            let qs: Vec<Query> = queries
                .iter()
                .enumerate()
                .map(|(i, (tenant, views, bytes))| Query {
                    id: QueryId(i as u64 + 1),
                    tenant: TenantId(*tenant),
                    arrival: 0.0,
                    template: format!("q{i}"),
                    required_views: views.iter().map(|&v| ViewId(v)).collect(),
                    bytes_read: *bytes,
                    compute_cost: 0.0,
                })
                .collect();
            let b = BatchUtilities::build(&ts, &vc, *budget, &qs, None);
            let mut rng = Pcg64::new(13);
            assert_batch_equivalence(&b, &mut rng);
            assert_welfare_equivalence(&b, &mut rng);
            Ok(())
        },
    );
}

/// The interning arena dedups without changing the v-matrix contents.
#[test]
fn config_space_rows_match_scaled_utilities() {
    use robus::alloc::ConfigSpace;
    let mut rng = Pcg64::new(77);
    for b in [table3(), table4(5)] {
        let space = ConfigSpace::pruned(&b, 30, &mut rng);
        // No duplicate masks after interning.
        for (i, a) in space.masks().iter().enumerate() {
            for bm in &space.masks()[i + 1..] {
                assert_ne!(a, bm, "duplicate mask survived interning");
            }
        }
        // Rows are exactly the (legacy-equivalent) scaled utilities.
        for (s, mask) in space.masks().iter().enumerate() {
            assert_eq!(space.v_row(s), b.scaled_utilities(mask).as_slice());
            assert_eq!(
                b.scaled_utilities(mask),
                legacy_scaled_utilities(&b, &mask.to_bools())
            );
        }
    }
}
