//! The elastic-membership correctness contract (ISSUE 4):
//!
//! 1. **Workload conservation through churn**: whatever the membership
//!    schedule — adds, drains, kills, or all three — every admitted
//!    query retires exactly once somewhere in the federation, including
//!    the queries re-routed off a killed shard.
//! 2. **Static runs stay static**: with an empty plan the elastic paths
//!    are inert (constant live set and budgets, no warm-ups, no
//!    membership records) and runs are deterministic. The bit-identity
//!    of static runs against `Coordinator::run` is pinned separately in
//!    `cluster_equivalence.rs`.
//! 3. **Fault-injection transients re-converge**: after a kill on the
//!    §5.3 grid, the windowed attainment spread returns to within 1.5×
//!    of its pre-kill level within 20 batches — the global accountant
//!    absorbs the transient.
//! 4. **Satellite regressions**: a fully starved tenant drives
//!    `speedup_spread` to ∞ instead of being dropped; adds warm up and
//!    re-split budgets; removes drain; replica decay fires and is
//!    recorded.

use robus::alloc::PolicyKind;
use robus::cluster::{speedup_spread, FederationConfig, MembershipAction, MembershipPlan};
use robus::coordinator::loop_::RunResult;
use robus::domain::query::QueryId;
use robus::experiments::runner::{run_federated, run_with_policies_serial};
use robus::experiments::setups::{self, ExperimentSetup};
use robus::sim::cluster::ClusterConfig;
use robus::sim::engine::QueryOutcome;

fn fed_with(n_shards: usize, plan: &str) -> FederationConfig {
    let mut f = FederationConfig::with_shards(n_shards);
    f.membership = MembershipPlan::parse(plan).expect("plan parses");
    f
}

fn sorted_ids(run: &RunResult) -> Vec<u64> {
    let mut ids: Vec<u64> = run.outcomes.iter().map(|o| o.id.0).collect();
    ids.sort_unstable();
    ids
}

fn serial_ids(setup: &ExperimentSetup) -> Vec<u64> {
    let serial = run_with_policies_serial(setup, &[PolicyKind::FastPf.build()]);
    sorted_ids(&serial.runs[0])
}

/// Every admitted query retires exactly once under any membership
/// schedule — sharding and resharding change *where* queries run, never
/// *whether*.
#[test]
fn conservation_across_membership_schedules() {
    let setup = setups::data_sharing_sales()[2].clone().quick(8);
    let expect = serial_ids(&setup);
    for plan in [
        "add@2",
        "kill@3",
        "remove@4",
        "add@1,kill@3,remove@5",
        "add@2,add@3,kill@4,kill@6",
    ] {
        let cfg = fed_with(3, plan);
        let policy = PolicyKind::FastPf.build();
        let result = run_federated(&setup, &cfg, policy.as_ref());
        assert_eq!(
            sorted_ids(&result.run),
            expect,
            "schedule '{plan}' lost or duplicated queries"
        );
        // Shard outcome counts partition the total.
        let per_shard: usize = result.per_shard.iter().map(|r| r.outcomes.len()).sum();
        assert_eq!(per_shard, expect.len(), "schedule '{plan}'");
        // Every scheduled event was applied and recorded.
        let n_events: usize = result.records.iter().map(|r| r.membership.len()).sum();
        assert_eq!(n_events, plan.split(',').count(), "schedule '{plan}'");
    }
}

/// An empty plan keeps every elastic path inert and the run
/// deterministic (the static bit-identity against the serial
/// coordinator is asserted in `cluster_equivalence.rs`).
#[test]
fn empty_plan_is_inert_and_deterministic() {
    let setup = setups::data_sharing_sales()[1].clone().quick(5);
    let total_budget = ClusterConfig::default().cache_budget;
    let run = || {
        let policy = PolicyKind::FastPf.build();
        run_federated(&setup, &FederationConfig::with_shards(3), policy.as_ref())
    };
    let a = run();
    for r in &a.records {
        assert!(r.membership.is_empty());
        assert!(r.decayed_views.is_empty());
        assert!(r.warming_shards.is_empty());
        assert_eq!(r.live_shards, 3);
        assert_eq!(r.shard_budget, total_budget / 3);
    }
    assert_eq!(a.rebalance_churn_bytes, 0);
    let b = run();
    assert_eq!(sorted_ids(&a.run), sorted_ids(&b.run));
    for (x, y) in a.run.outcomes.iter().zip(&b.run.outcomes) {
        assert_eq!(x.finish, y.finish);
    }
}

/// Kill-shard fault injection on a §5.3 grid cell: queries re-route to
/// survivors (conservation), the lost bytes and budget re-split are
/// recorded, and the windowed attainment spread re-converges to within
/// 1.5× of its pre-kill level within 20 batches.
fn assert_kill_recovers(setup: &ExperimentSetup) {
    let kill_at = 10usize;
    let cfg = fed_with(4, "kill@10");
    let policy = PolicyKind::FastPf.build();
    let result = run_federated(setup, &cfg, policy.as_ref());

    // Conservation including the re-routed queries.
    assert_eq!(sorted_ids(&result.run), serial_ids(setup), "{}", setup.name);

    // The event is recorded with the fault semantics: bytes lost, no
    // drain, views re-homed, budgets re-split 4 → 3 ways.
    let rec = &result.records[kill_at];
    assert_eq!(rec.membership.len(), 1, "{}", setup.name);
    let change = &rec.membership[0];
    assert_eq!(change.action, MembershipAction::Kill);
    assert_eq!(change.bytes_drained, 0);
    assert!(change.bytes_lost > 0, "victim had a cache to lose");
    assert!(change.views_moved > 0, "victim's views re-homed");
    let total_budget = ClusterConfig::default().cache_budget;
    assert_eq!(result.records[kill_at - 1].live_shards, 4);
    assert_eq!(result.records[kill_at - 1].shard_budget, total_budget / 4);
    assert_eq!(rec.live_shards, 3);
    assert_eq!(rec.shard_budget, total_budget / 3);
    // The victim's own history stops at the kill.
    let victim = &result.per_shard[change.shard];
    assert_eq!(victim.batches.len(), kill_at, "{}", setup.name);

    // Re-convergence: the 5-batch sliding attainment spread returns to
    // ≤1.5× the pre-kill spread within 20 batches of the fault.
    let w = 5usize;
    let pre = result.attainment_spread_window(kill_at - 2 * w, kill_at);
    assert!(
        pre.is_finite(),
        "{}: pre-kill spread must be finite, got {pre}",
        setup.name
    );
    let recovered = (kill_at..=kill_at + 20 - w)
        .any(|t| result.attainment_spread_window(t, t + w) <= pre * 1.5 + 1e-9);
    assert!(
        recovered,
        "{}: spread did not re-converge to ≤1.5× {pre:.3} within 20 batches",
        setup.name
    );
    // The transient report is well-formed around the event (its
    // recovery scan is pinned deterministically in the metrics unit
    // tests; here we only require a sane pre-event window).
    let t = result.transient(kill_at, w);
    assert!(t.pre_spread.is_finite(), "{}", setup.name);
    assert!(t.pre_queries_per_batch > 0.0, "{}", setup.name);
}

#[test]
fn kill_recovers_on_sales_grid() {
    assert_kill_recovers(&setups::data_sharing_sales()[1].clone().quick(32));
}

#[test]
fn kill_recovers_on_tenant_scaling_grid() {
    assert_kill_recovers(&setups::tenant_scaling()[1].clone().quick(32));
}

/// A live add: the joiner takes ~1/N of the views, budgets re-split,
/// and the joiner sits out the accountant for the warm-up window.
#[test]
fn add_shard_warms_up_and_resplits_budget() {
    let setup = setups::data_sharing_sales()[1].clone().quick(8);
    let cfg = fed_with(2, "add@3"); // default warm-up: 2 batches
    let policy = PolicyKind::FastPf.build();
    let result = run_federated(&setup, &cfg, policy.as_ref());

    assert_eq!(sorted_ids(&result.run), serial_ids(&setup));

    let rec = &result.records[3];
    assert_eq!(rec.membership.len(), 1);
    let change = &rec.membership[0];
    assert_eq!(change.action, MembershipAction::Add);
    assert_eq!(change.shard, 2, "the joiner gets the next fresh id");
    assert!(change.views_moved > 0, "the joiner must take views");
    assert_eq!(change.bytes_drained + change.bytes_lost, 0);

    let total_budget = ClusterConfig::default().cache_budget;
    assert_eq!(result.records[2].live_shards, 2);
    assert_eq!(result.records[2].shard_budget, total_budget / 2);
    assert_eq!(rec.live_shards, 3);
    assert_eq!(rec.shard_budget, total_budget / 3);

    // Warm-up: the joiner is excluded from the accountant for exactly
    // `warmup_batches` batches, then observed.
    assert_eq!(result.records[3].warming_shards, vec![2]);
    assert_eq!(result.records[4].warming_shards, vec![2]);
    assert!(result.records[5].warming_shards.is_empty());

    // The joiner's history starts at its birth batch.
    assert_eq!(result.per_shard.len(), 3);
    assert_eq!(result.per_shard[2].batches.len(), 8 - 3);
    assert_eq!(result.per_shard[2].batches[0].index, 3);
    assert_eq!(result.per_shard_budgets[2].len(), 8 - 3);
    assert!(result.per_shard_budgets[2]
        .iter()
        .all(|&b| b == total_budget / 3));
}

/// A planned remove drains: the leaver's cached bytes are charged to
/// the churn figure and its views re-home before routing.
#[test]
fn remove_shard_drains_and_rehomes() {
    let setup = setups::data_sharing_sales()[1].clone().quick(8);
    let cfg = fed_with(3, "remove@4");
    let policy = PolicyKind::FastPf.build();
    let result = run_federated(&setup, &cfg, policy.as_ref());

    assert_eq!(sorted_ids(&result.run), serial_ids(&setup));

    let rec = &result.records[4];
    let change = &rec.membership[0];
    assert_eq!(change.action, MembershipAction::Remove);
    assert_eq!(change.shard, 2, "default victim is the highest live id");
    assert_eq!(change.bytes_lost, 0, "a drain is not a fault");
    assert!(change.bytes_drained > 0, "the leaver had contents to drain");
    assert!(change.views_moved > 0);
    assert!(
        result.rebalance_churn_bytes >= change.bytes_drained,
        "drain bytes are charged to the churn figure"
    );
    assert_eq!(rec.live_shards, 2);
    // The leaver's history stops at the drain batch.
    assert_eq!(result.per_shard[2].batches.len(), 4);
}

/// Replica decay: with a low replication threshold on the rotating
/// hot/cold Sales windows, replicas are created while a view is hot and
/// decay once its demand share stays below the threshold, with the
/// decay recorded per batch.
#[test]
fn replica_decay_fires_and_is_recorded() {
    let setup = setups::data_sharing_sales()[0].clone().quick(12);
    let mut cfg = FederationConfig::with_shards(4);
    cfg.replicate_hot = Some(0.03);
    cfg.replica_decay = Some(1);
    let policy = PolicyKind::FastPf.build();
    let result = run_federated(&setup, &cfg, policy.as_ref());

    assert!(
        result.records.iter().any(|r| !r.replicated_views.is_empty()),
        "a 3% threshold on Zipf demand must replicate something"
    );
    assert!(
        result.records.iter().any(|r| !r.decayed_views.is_empty()),
        "rotating hot windows must decay some replica within 12 batches"
    );
    // Decay only ever evicts views that were replicated at some point.
    let replicated: std::collections::BTreeSet<usize> = result
        .records
        .iter()
        .flat_map(|r| r.replicated_views.iter().copied())
        .collect();
    for r in &result.records {
        for v in &r.decayed_views {
            assert!(replicated.contains(v), "decayed view {v} never replicated");
        }
    }
    assert_eq!(sorted_ids(&result.run), serial_ids(&setup));
}

/// Satellite regression: a tenant that was active in the baseline but
/// attained zero speedup is counted as fully starved — the spread is
/// ∞, not a quietly smaller max/min over the survivors.
#[test]
fn starved_tenant_spread_is_infinite() {
    let outcome = |id: u64, tenant: usize, exec: f64| QueryOutcome {
        id: QueryId(id),
        tenant,
        arrival: 0.0,
        start: 0.0,
        finish: exec,
        from_cache: false,
        bytes: 0,
    };
    let run_of = |outcomes: Vec<QueryOutcome>| RunResult {
        policy: "TEST",
        outcomes,
        batches: vec![],
        end_time: 100.0,
        n_tenants: 3,
        weights: vec![1.0; 3],
        host_wall_secs: 0.01,
        summary: robus::coordinator::loop_::ExecSummary::default(),
    };
    let baseline = run_of(vec![
        outcome(1, 0, 10.0),
        outcome(2, 1, 10.0),
        outcome(3, 2, 10.0),
    ]);
    // All three tenants served: finite spread.
    let healthy = run_of(vec![
        outcome(1, 0, 5.0),
        outcome(2, 1, 2.0),
        outcome(3, 2, 5.0),
    ]);
    let spread = speedup_spread(&healthy, &baseline);
    assert!(spread.is_finite());
    assert!((spread - 2.5).abs() < 1e-9, "got {spread}");
    // Tenant 1 fully starved (no queries retired): spread = ∞.
    let starved = run_of(vec![outcome(1, 0, 5.0), outcome(3, 2, 5.0)]);
    assert!(
        speedup_spread(&starved, &baseline).is_infinite(),
        "a fully starved tenant must drive the spread to infinity"
    );
}
