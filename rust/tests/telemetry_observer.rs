//! The telemetry contract (ISSUE 8): telemetry is a **pure observer**.
//! A SimClock replay with a full telemetry stack attached (trace sink,
//! snapshots, registry) is bit-identical — outcome by outcome, batch by
//! batch — to the same replay with telemetry off, for every driver:
//! serial, pipelined, and an 8-shard federated serve with membership,
//! replication, and rebalancing all live.
//!
//! Also here: the histogram-quantile accuracy property (registry
//! estimates vs `util::stats::percentile` exact answers). The trace
//! writer's drop-and-count backpressure contract is unit-tested next to
//! the writer itself (`src/telemetry/trace.rs`).

use robus::alloc::PolicyKind;
use robus::cluster::{AutoMembership, ServeFederationConfig};
use robus::coordinator::loop_::{CommonConfig, CoordinatorConfig, RunResult};
use robus::coordinator::service::AdmissionPolicy;
use robus::coordinator::ServeConfig;
use robus::domain::tenant::TenantSet;
use robus::session::Session;
use robus::sim::{ClusterConfig, SimEngine};
use robus::telemetry::{Histogram, Telemetry};
use robus::util::rng::Pcg64;
use robus::util::stats;
use robus::workload::generator::WorkloadGenerator;
use robus::workload::spec::{AccessSpec, TenantSpec};
use robus::workload::Universe;

/// A telemetry stack with every path live but no file/socket: JSONL
/// trace into `io::sink()`, snapshots on the run clock, registry
/// always-on.
fn full_telemetry() -> Telemetry {
    let mut tel = Telemetry::off();
    tel.trace_to(Box::new(std::io::sink()), 256);
    tel.snapshot_every(10.0);
    tel
}

fn specs(n: usize) -> Vec<TenantSpec> {
    (0..n)
        .map(|i| TenantSpec::new(AccessSpec::g(1 + i % 4), 20.0))
        .collect()
}

fn replay(pipelined: bool, tel: &Telemetry) -> RunResult {
    let universe = Universe::sales_only();
    let engine = SimEngine::new(ClusterConfig::default());
    let cfg = CoordinatorConfig {
        common: CommonConfig {
            batch_secs: 40.0,
            stateful_gamma: Some(2.0),
            seed: 42,
            warm_start: true,
            ..CommonConfig::default()
        },
        n_batches: 8,
    };
    let mut gen = WorkloadGenerator::new(specs(4), &universe, 42);
    let policy = PolicyKind::FastPf.build();
    let sess = Session::replay(&universe, TenantSet::equal(4), engine)
        .config(cfg)
        .telemetry(tel);
    if pipelined {
        sess.pipelined(2).run(&mut gen, policy.as_ref())
    } else {
        sess.run(&mut gen, policy.as_ref())
    }
}

/// Every simulated quantity of two runs must match exactly (bitwise on
/// the floats — no tolerance).
fn assert_bit_identical(off: &RunResult, on: &RunResult) {
    assert!(!off.outcomes.is_empty(), "degenerate run proves nothing");
    assert_eq!(off.outcomes.len(), on.outcomes.len());
    for (a, b) in off.outcomes.iter().zip(&on.outcomes) {
        assert_eq!(a.id, b.id);
        assert_eq!(a.tenant, b.tenant);
        assert_eq!(a.arrival, b.arrival);
        assert_eq!(a.start, b.start);
        assert_eq!(a.finish, b.finish);
        assert_eq!(a.from_cache, b.from_cache);
    }
    assert_eq!(off.batches.len(), on.batches.len());
    for (a, b) in off.batches.iter().zip(&on.batches) {
        assert_eq!(a.index, b.index);
        assert_eq!(a.n_queries, b.n_queries);
        assert_eq!(a.config, b.config);
        assert_eq!(a.cache_utilization, b.cache_utilization);
        assert_eq!(a.delta, b.delta);
        assert_eq!(a.exec_start, b.exec_start);
        assert_eq!(a.exec_end, b.exec_end);
    }
    assert_eq!(off.end_time, on.end_time);
}

#[test]
fn serial_replay_bit_identical_with_telemetry() {
    let off = replay(false, &Telemetry::off());
    let mut tel = full_telemetry();
    let on = replay(false, &tel);
    tel.shutdown();
    assert_bit_identical(&off, &on);
    // And the observer actually observed: one span per batch.
    assert_eq!(tel.metrics().batch_spans.get(), on.batches.len() as u64);
    assert_eq!(tel.metrics().queries_completed.get(), on.outcomes.len() as u64);
    assert_eq!(tel.metrics().trace_dropped.get(), 0);
}

#[test]
fn pipelined_replay_bit_identical_with_telemetry() {
    let off = replay(true, &Telemetry::off());
    let mut tel = full_telemetry();
    let on = replay(true, &tel);
    tel.shutdown();
    assert_bit_identical(&off, &on);
    assert_eq!(tel.metrics().batch_spans.get(), on.batches.len() as u64);
}

/// The hard case: 8 shards, worker threads, reactive membership armed,
/// hot-view replication + decay, periodic rebalance — every event
/// source live. Telemetry on vs off must still replay bit-identically
/// under SimClock.
#[test]
fn federated_8shard_replay_bit_identical_with_telemetry() {
    let cfg = ServeConfig {
        common: CommonConfig {
            batch_secs: 0.25,
            seed: 23,
            warm_start: true,
            ..CommonConfig::default()
        },
        duration_secs: 2.0,
        rate_per_sec: 800.0,
        n_tenants: 4,
        queue_capacity: 16_384,
        admission: AdmissionPolicy::Drop,
        verbose: false,
    };
    let mut fcfg = ServeFederationConfig::new(cfg, 8);
    fcfg.auto = Some(AutoMembership {
        lo_qps: 5.0,
        hi_qps: 5_000.0,
        window: 2,
        cooldown: 2,
    });
    fcfg.replicate_hot = Some(0.3);
    fcfg.replica_decay = Some(2);
    fcfg.rebalance_every = Some(3);

    let universe = Universe::sales_only();
    let tenants = TenantSet::equal(fcfg.serve.n_tenants);
    let engine = SimEngine::new(ClusterConfig::default());
    let policy = PolicyKind::FastPf.build();

    let off = Session::serve_federated(&universe, &tenants, &engine, fcfg.clone())
        .sim()
        .run(policy.as_ref());
    let mut tel = full_telemetry();
    let on = Session::serve_federated(&universe, &tenants, &engine, fcfg)
        .telemetry(&tel)
        .sim()
        .run(policy.as_ref());
    tel.shutdown();

    assert_bit_identical(&off.cluster.run, &on.cluster.run);
    assert_eq!(off.serve.admitted, on.serve.admitted);
    assert_eq!(off.serve.rejected, on.serve.rejected);
    assert_eq!(off.serve.completed, on.serve.completed);
    assert_eq!(off.serve.per_tenant_completed, on.serve.per_tenant_completed);
    assert_eq!(off.membership_events().len(), on.membership_events().len());
    assert_eq!(off.cluster.per_shard.len(), on.cluster.per_shard.len());
    for (a, b) in off.cluster.per_shard.iter().zip(&on.cluster.per_shard) {
        assert_eq!(a.outcomes.len(), b.outcomes.len());
    }

    // The registry agrees with the report on the conservation ledger.
    assert_eq!(tel.metrics().queries_admitted.get(), on.serve.admitted);
    assert_eq!(tel.metrics().queries_rejected.get(), on.serve.rejected);
    assert_eq!(tel.metrics().queries_completed.get(), on.serve.completed);
    // Router epochs: at least the initial publication reached the trace.
    assert!(tel.metrics().router_epochs.get() >= 1);
    assert_eq!(tel.metrics().trace_dropped.get(), 0);
}

/// Histogram quantile accuracy: the 2^(1/8) bucket ladder promises
/// estimates within one bucket ratio (≤ ~9% relative) of the exact
/// sample percentile for values inside the representable range, across
/// scales and skews.
#[test]
fn histogram_quantiles_track_exact_percentiles() {
    let mut rng = Pcg64::new(7);
    // Log-uniform over ~5 decades (0.01 .. 1000) — covers ms latencies
    // and batch sizes alike, nothing near the ladder's edges.
    let xs: Vec<f64> = (0..5000)
        .map(|_| 10f64.powf(rng.next_f64() * 5.0 - 2.0))
        .collect();
    let h = Histogram::new();
    for &x in &xs {
        h.record(x);
    }
    assert_eq!(h.count(), xs.len() as u64);
    let exact_sum: f64 = xs.iter().sum();
    assert!((h.sum() - exact_sum).abs() / exact_sum < 1e-3);

    let ps = [1.0, 10.0, 25.0, 50.0, 75.0, 90.0, 99.0, 99.9];
    let exact = stats::percentiles_of(&xs, &ps);
    for (&p, &e) in ps.iter().zip(&exact) {
        let est = h.quantile(p);
        let rel = (est - e).abs() / e;
        // One bucket ratio (2^(1/8) ≈ 1.09) plus rank-rounding slack.
        assert!(
            rel < 0.12,
            "p{p}: histogram {est} vs exact {e} (rel err {rel:.3})"
        );
    }
}

/// Degenerate inputs stay sane: empty histogram answers 0, one sample
/// answers (approximately) itself at any percentile.
#[test]
fn histogram_quantile_edge_cases() {
    let h = Histogram::new();
    assert_eq!(h.quantile(50.0), 0.0);
    h.record(2.5);
    for p in [0.0, 50.0, 100.0] {
        let est = h.quantile(p);
        assert!((est - 2.5).abs() / 2.5 < 0.09, "single sample p{p}: {est}");
    }
}
