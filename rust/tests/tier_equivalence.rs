//! The tentpole guarantee of the tiered cache (ISSUE 10): an SSD
//! capacity of **zero** is not "a small SSD" — it is bit-identical to
//! the pre-tier single-tier path. `TierSpec::single(budget)` with the
//! engine's own cache budget must replay exactly — same sampled
//! configurations, same cache transitions, same query outcomes — as
//! `tiers: None`, across the §5.3 experiment grid on every driver
//! (serial, pipelined, 1-shard federated).
//!
//! Also here: the demotion-before-drop byte-accounting conservation
//! invariants (every inter-tier byte shows up in exactly one
//! `CacheDelta` category, planes stay disjoint and within budget), and
//! the tier-aware warm-start shape check (a tier-budget re-split voids
//! carried solver state).

use robus::alloc::{BatchSignature, ConfigMask, Policy, PolicyKind};
use robus::cache::{CacheManager, TierAssignment, TierBudgets, TierCostModel, TierSpec};
use robus::cluster::{FederationConfig, MembershipPlan};
use robus::coordinator::loop_::RunResult;
use robus::domain::dataset::DatasetCatalog;
use robus::domain::query::{Query, QueryId};
use robus::domain::tenant::TenantSet;
use robus::domain::utility::{BatchUtilities, TierPlan};
use robus::domain::view::{ViewCatalog, ViewId, ViewKind};
use robus::experiments::runner::{
    run_federated, run_with_policies_pipelined, run_with_policies_serial,
};
use robus::experiments::setups::{self, ExperimentSetup};
use robus::sim::ClusterConfig;

/// The single-tier budget every runner engine uses
/// (`SimEngine::new(ClusterConfig::default())`).
fn engine_budget() -> u64 {
    ClusterConfig::default().cache_budget
}

fn policy_set() -> Vec<Box<dyn Policy>> {
    robus::experiments::runner::default_policies()
        .into_iter()
        .map(|k| k.build())
        .collect()
}

/// Full bitwise equality of two runs, down to the per-batch tier planes
/// and cache deltas. No tolerance anywhere.
fn assert_bit_identical(label: &str, a: &RunResult, b: &RunResult) {
    assert!(!a.outcomes.is_empty(), "{label}: degenerate run proves nothing");
    assert_eq!(a.outcomes.len(), b.outcomes.len(), "{label}");
    for (x, y) in a.outcomes.iter().zip(&b.outcomes) {
        assert_eq!(x.id, y.id, "{label}");
        assert_eq!(x.tenant, y.tenant, "{label}");
        assert_eq!(x.arrival, y.arrival, "{label}");
        assert_eq!(x.start, y.start, "{label}");
        assert_eq!(x.finish, y.finish, "{label}");
        assert_eq!(x.from_cache, y.from_cache, "{label}");
    }
    assert_eq!(a.batches.len(), b.batches.len(), "{label}");
    for (x, y) in a.batches.iter().zip(&b.batches) {
        assert_eq!(x.config, y.config, "{label} batch {}", x.index);
        assert_eq!(x.ssd, y.ssd, "{label} batch {}", x.index);
        assert_eq!(x.delta, y.delta, "{label} batch {}", x.index);
        assert_eq!(x.cache_utilization, y.cache_utilization, "{label}");
        assert_eq!(x.exec_start, y.exec_start, "{label}");
        assert_eq!(x.exec_end, y.exec_end, "{label}");
    }
    assert_eq!(a.end_time, b.end_time, "{label}");
}

/// In SSD-0 mode the tier plane must never materialize: empty SSD masks,
/// zero inter-tier bytes.
fn assert_tier_plane_empty(label: &str, r: &RunResult) {
    for b in &r.batches {
        assert!(b.ssd.ones().next().is_none(), "{label}: SSD plane non-empty");
        assert!(b.delta.demoted.is_empty(), "{label}: demotion in SSD-0 mode");
        assert!(b.delta.promoted.is_empty(), "{label}: promotion in SSD-0 mode");
        assert!(b.delta.ssd_loaded.is_empty(), "{label}: SSD load in SSD-0 mode");
    }
    assert_eq!(r.summary.bytes_demoted, 0, "{label}");
    assert_eq!(r.summary.bytes_promoted, 0, "{label}");
    assert_eq!(r.summary.bytes_ssd_loaded, 0, "{label}");
}

fn ssd0(setup: &ExperimentSetup) -> ExperimentSetup {
    setup
        .clone()
        .with_tiers(Some(TierSpec::single(engine_budget())))
}

/// Serial driver, full policy set, all four §5.3 Sales setups.
#[test]
fn ssd0_serial_bit_identical_across_grid() {
    for setup in setups::data_sharing_sales() {
        let setup = setup.quick(6);
        let legacy = run_with_policies_serial(&setup, &policy_set());
        let tiered = run_with_policies_serial(&ssd0(&setup), &policy_set());
        assert_eq!(legacy.runs.len(), tiered.runs.len());
        for (l, t) in legacy.runs.iter().zip(&tiered.runs) {
            assert_eq!(l.policy, t.policy);
            let label = format!("{}/{} serial", setup.name, l.policy);
            assert_bit_identical(&label, l, t);
            assert_tier_plane_empty(&label, t);
        }
    }
}

/// Pipelined driver (depth 2): the planner's tier mirror must not
/// perturb the overlap schedule.
#[test]
fn ssd0_pipelined_bit_identical() {
    for setup in setups::data_sharing_sales() {
        let setup = setup.quick(6);
        let legacy = run_with_policies_pipelined(&setup, &policy_set(), 2);
        let tiered = run_with_policies_pipelined(&ssd0(&setup), &policy_set(), 2);
        for (l, t) in legacy.runs.iter().zip(&tiered.runs) {
            let label = format!("{}/{} pipelined", setup.name, l.policy);
            assert_bit_identical(&label, l, t);
            assert_tier_plane_empty(&label, t);
        }
    }
}

/// 1-shard federation: the shard's tier-budget split of a single-tier
/// spec is the spec itself, so the merged run replays bit-identically.
#[test]
fn ssd0_federated_one_shard_bit_identical() {
    let fed = FederationConfig::with_shards(1);
    for setup in setups::data_sharing_sales() {
        let setup = setup.quick(6);
        let policy: Box<dyn Policy> = PolicyKind::FastPf.build();
        let legacy = run_federated(&setup, &fed, policy.as_ref());
        let tiered = run_federated(&ssd0(&setup), &fed, policy.as_ref());
        let label = format!("{} federated-1", setup.name);
        assert_bit_identical(&label, &legacy.run, &tiered.run);
        assert_tier_plane_empty(&label, &tiered.run);
    }
}

/// Deterministic demotion-before-drop at the `CacheManager` level:
/// dropped RAM residents pack into spare SSD capacity in ascending
/// view-id order, every byte lands in exactly one delta category, and
/// the planes stay disjoint and within budget.
#[test]
fn demotion_before_drop_byte_conservation() {
    let sizes = vec![100u64, 100, 100, 100];
    let spec = TierSpec {
        budgets: TierBudgets { ram: 200, ssd: 200 },
        cost: TierCostModel::default(),
    };
    let mut cache = CacheManager::new_tiered(spec, sizes.clone());
    let mask = |bits: [bool; 4]| ConfigMask::from_bools(&bits);

    // Batch 1: load views 0 and 1 into RAM.
    let d = cache.update_tiered(&TierAssignment {
        ram: mask([true, true, false, false]),
        ssd: mask([false, false, false, false]),
    });
    assert_eq!(d.loaded, vec![0, 1]);
    assert_eq!(d.bytes_loaded, 200);
    assert!(d.demoted.is_empty() && d.evicted.is_empty());

    // Batch 2: the solver keeps only view 2 in RAM and names no SSD
    // plane. Views 0 and 1 leave RAM; both fit in the empty SSD tier,
    // so *neither* is dropped — eviction is demotion first.
    let d = cache.update_tiered(&TierAssignment {
        ram: mask([false, false, true, false]),
        ssd: mask([false, false, false, false]),
    });
    assert_eq!(d.loaded, vec![2]);
    assert_eq!(d.demoted, vec![0, 1]);
    assert_eq!(d.bytes_demoted, 200);
    assert!(d.evicted.is_empty(), "demotion must preempt the drop");
    assert_eq!(cache.ssd_used_bytes(), 200);
    assert_eq!(cache.tier_of(0), Some(robus::cache::Tier::Ssd));

    // Batch 3: view 0 comes back to RAM — a promotion, not a load; view
    // 2 stays in RAM, view 1 stays on SSD. Nothing leaves residency.
    let d = cache.update_tiered(&TierAssignment {
        ram: mask([true, false, true, false]),
        ssd: mask([false, true, false, false]),
    });
    assert_eq!(d.promoted, vec![0]);
    assert_eq!(d.bytes_promoted, 100);
    assert!(d.loaded.is_empty());
    assert!(d.evicted.is_empty());
    assert_eq!(cache.tier_of(0), Some(robus::cache::Tier::Ram));
    assert_eq!(cache.tier_of(1), Some(robus::cache::Tier::Ssd));

    // Overflow: a fresh cache with SSD room for one view demotes the
    // lowest id and genuinely evicts the rest.
    let spec = TierSpec {
        budgets: TierBudgets { ram: 200, ssd: 100 },
        cost: TierCostModel::default(),
    };
    let mut cache = CacheManager::new_tiered(spec, sizes);
    cache.update_tiered(&TierAssignment {
        ram: mask([true, true, false, false]),
        ssd: mask([false, false, false, false]),
    });
    let d = cache.update_tiered(&TierAssignment {
        ram: mask([false, false, true, true]),
        ssd: mask([false, false, false, false]),
    });
    assert_eq!(d.demoted, vec![0], "ascending-id fill takes view 0");
    assert_eq!(d.evicted, vec![1], "no SSD room left for view 1");
    assert_eq!(d.bytes_demoted, 100);
    assert_eq!(d.bytes_evicted, 100);
}

/// End-to-end tiered replay: reconstruct both tier planes batch by
/// batch from the recorded deltas and check every conservation
/// invariant — transitions act only on resident views, the rebuilt RAM
/// plane equals the recorded configuration, the solver's SSD plane is a
/// subset of the resolved one, budgets hold, and the streaming summary
/// equals the per-batch sums.
#[test]
fn tiered_replay_conserves_bytes_and_planes() {
    let budgets = TierBudgets {
        ram: engine_budget() / 8,
        ssd: engine_budget(),
    };
    let setup = setups::data_sharing_sales()[1]
        .clone()
        .quick(8)
        .with_tiers(Some(TierSpec {
            budgets,
            cost: TierCostModel::default(),
        }));
    let sizes: Vec<u64> = {
        let u = robus::workload::Universe::sales_only();
        u.views.iter().map(|v| v.cached_bytes).collect()
    };
    let out = run_with_policies_serial(&setup, &[PolicyKind::FastPf.build()]);
    let run = &out.runs[0];
    assert!(!run.batches.is_empty());

    let n = sizes.len();
    let mut ram = ConfigMask::empty(n);
    let mut ssd = ConfigMask::empty(n);
    let bytes_of = |views: &[usize]| -> u64 { views.iter().map(|&v| sizes[v]).sum() };
    let (mut demoted_total, mut promoted_total, mut ssd_loaded_total) = (0u64, 0u64, 0u64);
    for b in &run.batches {
        let d = &b.delta;
        // Per-category byte sums must match the view sizes exactly.
        assert_eq!(d.bytes_loaded, bytes_of(&d.loaded));
        assert_eq!(d.bytes_evicted, bytes_of(&d.evicted));
        assert_eq!(d.bytes_ssd_loaded, bytes_of(&d.ssd_loaded));
        assert_eq!(d.bytes_demoted, bytes_of(&d.demoted));
        assert_eq!(d.bytes_promoted, bytes_of(&d.promoted));
        // Transitions act on the tiers they claim to act on.
        for &v in &d.loaded {
            assert!(!ram.get(v) && !ssd.get(v), "load of a resident view");
            ram.set(v, true);
        }
        for &v in &d.ssd_loaded {
            assert!(!ram.get(v) && !ssd.get(v), "SSD load of a resident view");
            ssd.set(v, true);
        }
        for &v in &d.demoted {
            assert!(ram.get(v), "demotion of a non-RAM view");
            ram.set(v, false);
            ssd.set(v, true);
        }
        for &v in &d.promoted {
            assert!(ssd.get(v), "promotion of a non-SSD view");
            ssd.set(v, false);
            ram.set(v, true);
        }
        for &v in &d.evicted {
            assert!(ram.get(v) || ssd.get(v), "eviction of a non-resident view");
            ram.set(v, false);
            ssd.set(v, false);
        }
        // The rebuilt RAM plane is the recorded configuration; the
        // solver's SSD plane is contained in the resolved one (the
        // demotion fill only ever adds); planes stay disjoint.
        assert_eq!(ram, b.config, "batch {}", b.index);
        assert!(!ram.intersects(&ssd), "batch {}", b.index);
        for v in b.ssd.ones() {
            assert!(ssd.get(v), "batch {}: solver SSD view {v} not resident", b.index);
        }
        // Budgets hold on both tiers.
        let ram_bytes: u64 = ram.ones().map(|v| sizes[v]).sum();
        let ssd_bytes: u64 = ssd.ones().map(|v| sizes[v]).sum();
        assert!(ram_bytes <= budgets.ram, "batch {}: RAM over budget", b.index);
        assert!(ssd_bytes <= budgets.ssd, "batch {}: SSD over budget", b.index);
        demoted_total += d.bytes_demoted;
        promoted_total += d.bytes_promoted;
        ssd_loaded_total += d.bytes_ssd_loaded;
    }
    assert_eq!(run.summary.bytes_demoted, demoted_total);
    assert_eq!(run.summary.bytes_promoted, promoted_total);
    assert_eq!(run.summary.bytes_ssd_loaded, ssd_loaded_total);
}

/// A tier-budget re-split is a *shape* change for warm-started solves:
/// `BatchSignature::same_shape` goes false when the SSD budget moves
/// (total/N′ after a membership event), when the discount moves, or
/// when tiering turns on at all — so carried optima priced under the
/// old plan can never be reused.
#[test]
fn warm_start_signature_voids_on_tier_resplit() {
    let mut ds = DatasetCatalog::new();
    let mut vc = ViewCatalog::new();
    for v in 0..3 {
        let d = ds.add(&format!("d{v}"), 100);
        vc.add(&format!("v{v}"), d, ViewKind::BaseTable, 100, 100);
    }
    let mut ts = TenantSet::new();
    let t0 = ts.add("a", 1.0);
    let t1 = ts.add("b", 1.0);
    let queries = vec![
        Query {
            id: QueryId(1),
            tenant: t0,
            arrival: 0.0,
            template: "qa".into(),
            required_views: vec![ViewId(0)],
            bytes_read: 10,
            compute_cost: 0.0,
        },
        Query {
            id: QueryId(2),
            tenant: t1,
            arrival: 0.0,
            template: "qb".into(),
            required_views: vec![ViewId(1), ViewId(2)],
            bytes_read: 10,
            compute_cost: 0.0,
        },
    ];
    let batch = |tier: Option<TierPlan>| {
        BatchUtilities::build(&ts, &vc, 200.0, &queries, None).with_tier(tier)
    };
    let plan = |ssd_budget: f64, discount: f64| TierPlan { ssd_budget, discount };

    let single = BatchSignature::of(&batch(None));
    let tiered = BatchSignature::of(&batch(Some(plan(4000.0, 0.8))));
    let resplit = BatchSignature::of(&batch(Some(plan(2000.0, 0.8))));
    let repriced = BatchSignature::of(&batch(Some(plan(4000.0, 0.5))));
    let same = BatchSignature::of(&batch(Some(plan(4000.0, 0.8))));

    assert!(!single.same_shape(&tiered), "turning tiering on is a shape change");
    assert!(!tiered.same_shape(&resplit), "SSD re-split must void carried state");
    assert!(!tiered.same_shape(&repriced), "cost-model change must void carried state");
    assert!(tiered.same_shape(&same), "identical plan carries state");
    // The view structure is tier-independent: only the plan bits moved.
    assert_eq!(single.view_sigs, tiered.view_sigs);
}

/// Elastic federation under tiering: a live shard add re-splits both
/// tier budgets mid-run with warm-started solves carried per shard. The
/// run must stay fully deterministic (two identical invocations are
/// bit-identical) and keep the tier accounting conserved globally.
#[test]
fn tiered_federation_resplit_is_deterministic() {
    let mut setup = setups::data_sharing_sales()[1].clone().quick(8).with_tiers(Some(
        TierSpec {
            budgets: TierBudgets {
                ram: engine_budget() / 8,
                ssd: engine_budget(),
            },
            cost: TierCostModel::default(),
        },
    ));
    setup.warm_start = true;
    let mut fed = FederationConfig::with_shards(2);
    fed.membership = MembershipPlan::parse("add@3").expect("static plan parses");
    fed.warm_start = true;

    let policy: Box<dyn Policy> = PolicyKind::FastPf.build();
    let a = run_federated(&setup, &fed, policy.as_ref());
    let b = run_federated(&setup, &fed, policy.as_ref());
    assert_bit_identical("tiered resplit", &a.run, &b.run);
    assert_eq!(a.membership_events().len(), 1, "the add must fire");
    // The merged run still accounts inter-tier traffic coherently:
    // nothing was promoted that was never demoted or SSD-loaded.
    let s = &a.run.summary;
    assert!(
        s.bytes_promoted <= s.bytes_demoted + s.bytes_ssd_loaded,
        "promoted {} > demoted {} + ssd_loaded {}",
        s.bytes_promoted,
        s.bytes_demoted,
        s.bytes_ssd_loaded,
    );
}
