//! The federated-serving contract (ISSUE 5):
//!
//! 1. `robus serve --shards 1` preserves single-node serve semantics —
//!    the sharded sim driver reproduces `coordinator::service::serve_sim`
//!    outcome by outcome (same admitted set, same batch cuts, same
//!    sampled configurations, same simulated finish times).
//! 2. Reactive membership fires deterministically under `SimClock`: a
//!    sustained overload triggers an add, sustained idleness triggers a
//!    drain — and workload is conserved through both (queries admitted
//!    to a draining shard's queue are re-homed, never dropped).
//!
//! Everything here runs on the deterministic sim drivers: no wall-clock
//! sleeps, no flaky timing.

use robus::alloc::PolicyKind;
use robus::cluster::{AutoMembership, MembershipAction, ServeFederationConfig};
use robus::coordinator::loop_::CommonConfig;
use robus::coordinator::service::AdmissionPolicy;
use robus::coordinator::ServeConfig;
use robus::domain::tenant::TenantSet;
use robus::session::Session;
use robus::sim::{ClusterConfig, SimEngine};
use robus::workload::Universe;

fn base_cfg() -> ServeConfig {
    ServeConfig {
        common: CommonConfig {
            batch_secs: 0.25,
            seed: 23,
            warm_start: true,
            ..CommonConfig::default()
        },
        duration_secs: 2.0,
        rate_per_sec: 300.0,
        n_tenants: 3,
        queue_capacity: 16_384,
        admission: AdmissionPolicy::Drop,
        verbose: false,
    }
}

fn run_federated(fcfg: &ServeFederationConfig) -> robus::cluster::FederatedServeReport {
    let universe = Universe::sales_only();
    let tenants = TenantSet::equal(fcfg.serve.n_tenants);
    let engine = SimEngine::new(ClusterConfig::default());
    let policy = PolicyKind::FastPf.build();
    Session::serve_federated(&universe, &tenants, &engine, fcfg.clone())
        .sim()
        .run(policy.as_ref())
}

/// Acceptance: `--shards 1` preserves single-node serve semantics. The
/// sharded path at one shard must reproduce the single-node sim driver
/// exactly on every simulated quantity.
#[test]
fn one_shard_serving_matches_single_node_serve() {
    let cfg = base_cfg();
    let universe = Universe::sales_only();
    let tenants = TenantSet::equal(cfg.n_tenants);
    let engine = SimEngine::new(ClusterConfig::default());
    let policy = PolicyKind::FastPf.build();

    let (single_report, single_run) = Session::serve(&universe, &tenants, &engine)
        .config(cfg.clone())
        .sim()
        .run(policy.as_ref());
    let fcfg = ServeFederationConfig::new(cfg, 1);
    let fed = Session::serve_federated(&universe, &tenants, &engine, fcfg)
        .sim()
        .run(policy.as_ref());

    // Simulated outcomes are identical, query by query.
    let fed_run = &fed.cluster.run;
    assert!(single_run.outcomes.len() > 100, "workload too small to be meaningful");
    assert_eq!(single_run.outcomes.len(), fed_run.outcomes.len());
    for (a, b) in single_run.outcomes.iter().zip(&fed_run.outcomes) {
        assert_eq!(a.id, b.id);
        assert_eq!(a.tenant, b.tenant);
        assert_eq!(a.start, b.start);
        assert_eq!(a.finish, b.finish);
        assert_eq!(a.from_cache, b.from_cache);
    }
    // Batch cuts and sampled configurations are identical.
    assert_eq!(single_run.batches.len(), fed_run.batches.len());
    for (a, b) in single_run.batches.iter().zip(&fed_run.batches) {
        assert_eq!(a.n_queries, b.n_queries);
        assert_eq!(a.config, b.config);
        assert_eq!(a.exec_start, b.exec_start);
        assert_eq!(a.exec_end, b.exec_end);
    }
    // The deterministic report surface matches (host-measured figures —
    // elapsed seconds, solve percentiles — are excluded by design).
    assert_eq!(single_report.completed, fed.serve.completed);
    assert_eq!(single_report.admitted, fed.serve.admitted);
    assert_eq!(single_report.rejected, fed.serve.rejected);
    assert_eq!(single_report.batches, fed.serve.batches);
    assert_eq!(single_report.per_tenant_completed, fed.serve.per_tenant_completed);
    assert_eq!(single_report.queries_per_sec, fed.serve.queries_per_sec);
    assert_eq!(single_report.hit_ratio, fed.serve.hit_ratio);
    assert_eq!(single_report.max_batch, fed.serve.max_batch);
    assert_eq!(
        single_report.mean_admit_wait_ms,
        fed.serve.mean_admit_wait_ms
    );
    assert_eq!(
        single_report.throughput_fairness,
        fed.serve.throughput_fairness
    );
    // And no federation machinery fired.
    assert!(fed.membership_events().is_empty());
    assert_eq!(fed.live_shards_final(), 1);
}

/// Acceptance: a reactive add fires under sustained overload,
/// deterministically, with workload conservation.
#[test]
fn reactive_add_fires_under_sustained_overload() {
    let mut cfg = base_cfg();
    cfg.rate_per_sec = 400.0; // 100 queries per 0.25s batch
    let mut fcfg = ServeFederationConfig::new(cfg, 1);
    // Every batch is far above hi=100 q/s: the overload streak trips
    // after `window` batches and the federation grows.
    fcfg.auto = Some(AutoMembership {
        lo_qps: 5.0,
        hi_qps: 100.0,
        window: 2,
        cooldown: 2,
    });
    let r = run_federated(&fcfg);

    let adds: Vec<_> = r
        .membership_events()
        .iter()
        .filter(|(_, c)| c.action == MembershipAction::Add)
        .map(|(b, c)| (*b, c.shard, c.views_moved))
        .collect();
    assert!(!adds.is_empty(), "sustained overload never triggered an add");
    // The joiner took a nonempty slice of the view universe.
    assert!(adds[0].2 > 0, "add re-homed no views: {adds:?}");
    assert!(r.live_shards_final() > 1);
    // Conservation through the growth: everything admitted was served.
    assert_eq!(r.serve.completed, r.serve.admitted);
    // The joiner warmed up outside the accountant for its first batches.
    let add_batch = adds[0].0;
    let rec = &r.cluster.records[add_batch];
    assert!(
        rec.warming_shards.contains(&adds[0].1),
        "joiner not warming at its birth batch"
    );
    // Budgets re-split to total/N' from the add batch on.
    assert!(rec.shard_budget < r.cluster.records[add_batch - 1].shard_budget);

    // Deterministic under SimClock: a second run replays identically.
    let r2 = run_federated(&fcfg);
    assert_eq!(r.serve.completed, r2.serve.completed);
    assert_eq!(
        r.membership_events()
            .iter()
            .map(|(b, c)| (*b, c.action, c.shard))
            .collect::<Vec<_>>(),
        r2.membership_events()
            .iter()
            .map(|(b, c)| (*b, c.action, c.shard))
            .collect::<Vec<_>>(),
    );
    for (a, b) in r.cluster.run.outcomes.iter().zip(&r2.cluster.run.outcomes) {
        assert_eq!(a.id, b.id);
        assert_eq!(a.finish, b.finish);
    }
}

/// Acceptance + satellite: a reactive drain fires under sustained
/// idleness, and queries admitted to the draining shard's queue are
/// re-homed to survivors — conservation holds through auto membership.
#[test]
fn reactive_drain_rehomes_queued_work() {
    let mut cfg = base_cfg();
    cfg.rate_per_sec = 60.0; // ~5 queries/shard/batch across 3 shards
    cfg.duration_secs = 3.0;
    let mut fcfg = ServeFederationConfig::new(cfg, 3);
    // Every shard runs far below lo=40 q/s: the idlest drains.
    fcfg.auto = Some(AutoMembership {
        lo_qps: 40.0,
        hi_qps: 400.0,
        window: 2,
        cooldown: 2,
    });
    let r = run_federated(&fcfg);

    let drains: Vec<_> = r
        .membership_events()
        .iter()
        .filter(|(_, c)| c.action == MembershipAction::Remove)
        .map(|(b, c)| (*b, c.shard))
        .collect();
    assert!(!drains.is_empty(), "sustained idleness never triggered a drain");
    assert!(r.live_shards_final() < 3);
    // Never below one live shard.
    assert!(r.cluster.records.iter().all(|rec| rec.live_shards >= 1));

    // THE conservation contract: every admitted query completed — the
    // retiring shard's queued arrivals were re-homed, not dropped.
    assert_eq!(
        r.serve.completed, r.serve.admitted,
        "drain dropped admitted work: admitted={} completed={}",
        r.serve.admitted, r.serve.completed
    );
    // The retired shard executed only the batches before its drain.
    let (drain_batch, victim) = drains[0];
    let victim_run = &r.cluster.per_shard[victim];
    assert_eq!(victim_run.batches.len(), drain_batch);
    // Per-shard outcomes still partition the merged run.
    let per: usize = r.cluster.per_shard.iter().map(|s| s.outcomes.len()).sum();
    assert_eq!(per as u64, r.serve.completed);

    // Deterministic replay.
    let r2 = run_federated(&fcfg);
    assert_eq!(r.serve.completed, r2.serve.completed);
    assert_eq!(
        r.membership_events().len(),
        r2.membership_events().len()
    );
}

/// The drain victim's *backlog at drain time* specifically: run with a
/// batch window long enough that the drain decision happens while
/// arrivals are queued, and check none of them vanish.
#[test]
fn drain_with_queued_backlog_conserves_every_query() {
    let mut cfg = base_cfg();
    cfg.rate_per_sec = 100.0;
    cfg.duration_secs = 4.0;
    cfg.common.batch_secs = 0.5; // ~50 arrivals queued at every cut
    let mut fcfg = ServeFederationConfig::new(cfg, 2);
    fcfg.auto = Some(AutoMembership {
        lo_qps: 90.0, // both shards always "idle": drain fires ASAP
        hi_qps: 900.0,
        window: 1,
        cooldown: 1,
    });
    let r = run_federated(&fcfg);
    let drains = r
        .membership_events()
        .iter()
        .filter(|(_, c)| c.action == MembershipAction::Remove)
        .count();
    assert_eq!(drains, 1, "two shards can drain exactly once");
    assert_eq!(r.live_shards_final(), 1);
    assert_eq!(r.serve.completed, r.serve.admitted);
    assert!(r.serve.rejected == 0, "nothing should shed at this rate");
}

/// Default auto bounds bracket the configured fair share: a federation
/// serving exactly its target rate stays put (the nightly soak's
/// stability assumption).
#[test]
fn default_auto_bounds_are_stable_at_target_rate() {
    let mut cfg = base_cfg();
    cfg.rate_per_sec = 400.0;
    // Two shards: fair share 200 q/s → add above 400, drain below 50.
    // Even with hash-placement skew no shard approaches either bound.
    let mut fcfg = ServeFederationConfig::new(cfg, 2);
    fcfg.auto = Some(
        AutoMembership::parse("auto")
            .unwrap()
            .resolve(fcfg.serve.rate_per_sec, fcfg.n_shards)
            .unwrap(),
    );
    let r = run_federated(&fcfg);
    assert!(
        r.membership_events().is_empty(),
        "steady target-rate load fired events: {:?}",
        r.membership_events()
    );
    assert_eq!(r.live_shards_final(), 2);
    assert_eq!(r.serve.completed, r.serve.admitted);
}
