//! Stateful cache path coverage (§5.4 / Figure 12): the γ boost
//! application, incremental `CacheDelta` load/evict/materialization
//! accounting across consecutive updates, and a Figure 12-shaped quick
//! regression (the stateful boost suppresses cache churn at small batch
//! sizes).

use robus::alloc::{ConfigMask, PolicyKind};
use robus::cache::CacheManager;
use robus::experiments::runner::run_with_policies;
use robus::experiments::setups;

#[test]
fn boost_vector_marks_exactly_the_cached_views() {
    let mut cm = CacheManager::new(1000, vec![100; 70]);
    let mut target = ConfigMask::empty(70);
    // Multi-word mask: views on both sides of the 64-bit boundary.
    for v in [0usize, 3, 63, 64, 69] {
        target.set(v, true);
    }
    cm.update(&target);
    let boost = CacheManager::boost_vector(cm.cached(), 2.5);
    assert_eq!(boost.len(), 70);
    for v in 0..70 {
        let expect = if target.get(v) { 2.5 } else { 1.0 };
        assert_eq!(boost[v], expect, "view {v}");
    }
    // The pipelined planner's mirror path agrees bit-for-bit.
    assert_eq!(CacheManager::boost_vector(&target, 2.5), boost);
}

#[test]
fn delta_accounting_across_consecutive_updates() {
    let sizes = vec![40u64, 50, 30, 20];
    let mut cm = CacheManager::new(120, sizes.clone());

    let d1 = cm.update(&ConfigMask::from_indices(4, &[0, 1]));
    assert_eq!((d1.bytes_loaded, d1.bytes_evicted), (90, 0));

    // Touch view 0 (materializes); view 1 stays in flight.
    assert!(cm.charge_materialization(0));

    let d2 = cm.update(&ConfigMask::from_indices(4, &[0, 2, 3]));
    assert_eq!(d2.loaded, vec![2, 3]);
    assert_eq!(d2.evicted, vec![1]);
    assert_eq!((d2.bytes_loaded, d2.bytes_evicted), (50, 50));

    let stats = cm.transition_stats();
    assert_eq!(stats.updates, 2);
    assert_eq!(stats.views_loaded, 4);
    assert_eq!(stats.views_evicted, 1);
    assert_eq!(stats.bytes_loaded, 140);
    assert_eq!(stats.bytes_evicted, 50);
    assert_eq!(stats.materializations, 1);
    assert_eq!(stats.bytes_materialized, 40);
    // View 1 was evicted before any query touched it: wasted churn.
    assert_eq!(stats.cancelled_loads, 1);

    // A view re-entering the cache is charged again on first touch.
    let d3 = cm.update(&ConfigMask::from_indices(4, &[1, 2, 3]));
    assert_eq!(d3.loaded, vec![1]);
    assert_eq!(d3.evicted, vec![0]);
    assert!(cm.charge_materialization(1));
    assert!(!cm.charge_materialization(1));
    assert_eq!(cm.transition_stats().materializations, 2);
}

/// Figure 12 shape: at a small batch interval, the stateful γ boost
/// makes already-cached views likelier to stay, so the total bytes
/// moved through the cache (the materialization churn the real system
/// pays) must not exceed the stateless run's.
#[test]
fn fig12_shaped_stateful_churn_regression() {
    let cells = setups::batch_size_sweep();
    let find = |secs: f64, stateful: bool| {
        cells
            .iter()
            .find(|(s, g)| s.batch_secs == secs && g.is_some() == stateful)
            .map(|(s, _)| s.clone())
            .expect("sweep cell exists")
    };
    let policies = || -> Vec<Box<dyn robus::alloc::Policy>> {
        vec![PolicyKind::FastPf.build()]
    };
    let stateless = run_with_policies(&find(20.0, false).quick(8), &policies());
    let stateful = run_with_policies(&find(20.0, true).quick(8), &policies());
    let churn = |out: &robus::experiments::runner::ExperimentOutput| -> u64 {
        let (loaded, _evicted) = out.runs[0].cache_bytes_moved();
        loaded
    };
    let (cl, cs) = (churn(&stateless), churn(&stateful));
    // Allow a sliver of sampling noise: the allocation is randomized,
    // so an occasional extra load can slip into the stateful run.
    assert!(
        cs as f64 <= cl as f64 * 1.05,
        "stateful loaded {cs} bytes > stateless {cl} bytes"
    );
    // Both runs actually exercised the cache.
    assert!(cl > 0);
    assert!(stateful.runs[0].hit_ratio() >= 0.0);
}
