"""Kernel vs pure-jnp-reference correctness (the CORE L1 signal), with
hypothesis sweeping input values over the fixed padded shapes."""

import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st
from numpy.testing import assert_allclose

from compile.kernels import LS, NC, NQ, NT, NV
from compile.kernels.config_utils import config_utils
from compile.kernels.mmf_step import mmf_step
from compile.kernels.pf_step import pf_step
from compile.kernels.ref import config_utils_ref, mmf_step_ref, pf_step_ref


def rng(seed):
    return np.random.default_rng(seed)


def random_pf_inputs(seed, n_active=4, n_configs=10):
    r = rng(seed)
    v = np.zeros((NT, NC), np.float32)
    v[:n_active, :n_configs] = r.uniform(0.0, 1.0, (n_active, n_configs))
    wl = np.zeros(NT, np.float32)
    wl[:n_active] = 1.0
    cmask = np.zeros(NC, np.float32)
    cmask[:n_configs] = 1.0
    x = np.zeros(NC, np.float32)
    x[:n_configs] = r.uniform(0.0, 0.3, n_configs)
    steps = np.concatenate(
        [[0.0], 2.0 * 0.35 ** np.arange(LS - 1)]
    ).astype(np.float32)
    return x, v, wl, cmask, steps


@settings(max_examples=20, deadline=None)
@given(
    seed=st.integers(0, 2**31 - 1),
    n_active=st.integers(1, NT),
    n_configs=st.integers(1, NC),
)
def test_pf_step_matches_ref(seed, n_active, n_configs):
    x, v, wl, cmask, steps = random_pf_inputs(seed, n_active, n_configs)
    got = np.asarray(pf_step(x, v, wl, cmask, steps))
    want = np.asarray(pf_step_ref(
        jnp.asarray(x), jnp.asarray(v), jnp.asarray(wl),
        jnp.asarray(cmask), jnp.asarray(steps)))
    assert_allclose(got, want, rtol=1e-5, atol=1e-6)
    # Projected and masked.
    assert (got >= 0).all()
    assert (got[cmask == 0.0] == 0).all()


@settings(max_examples=20, deadline=None)
@given(seed=st.integers(0, 2**31 - 1), n_active=st.integers(1, NT))
def test_mmf_step_matches_ref(seed, n_active):
    r = rng(seed)
    v = np.zeros((NT, NC), np.float32)
    v[:n_active, :12] = r.uniform(0.0, 1.0, (n_active, 12))
    tmask = np.zeros(NT, np.float32)
    tmask[:n_active] = 1.0
    w = tmask / n_active
    got_w, got_pick = mmf_step(w, v, tmask, 0.2)
    want_w, want_pick = mmf_step_ref(
        jnp.asarray(w), jnp.asarray(v), jnp.asarray(tmask), 0.2)
    assert_allclose(np.asarray(got_w), np.asarray(want_w), rtol=1e-5, atol=1e-7)
    assert_allclose(np.asarray(got_pick), np.asarray(want_pick))
    # One-hot pick; weights stay a distribution over active tenants.
    assert np.asarray(got_pick).sum() == 1.0
    assert abs(np.asarray(got_w).sum() - 1.0) < 1e-5


@settings(max_examples=15, deadline=None)
@given(seed=st.integers(0, 2**31 - 1))
def test_config_utils_matches_ref(seed):
    r = rng(seed)
    nq, nv, nt, ncfg = 20, 10, 4, 8
    needs = np.zeros((NQ, NV), np.float32)
    needs[:nq, :nv] = (r.uniform(size=(nq, nv)) < 0.25)
    # Ensure non-empty requirement rows.
    for q in range(nq):
        if needs[q].sum() == 0:
            needs[q, r.integers(nv)] = 1.0
    count = needs.sum(axis=1).astype(np.float32)
    qutil = np.zeros(NQ, np.float32)
    qutil[:nq] = r.uniform(0.5, 5.0, nq)
    qtenant = np.zeros((NT, NQ), np.float32)
    for q in range(nq):
        qtenant[r.integers(nt), q] = 1.0
    configs = np.zeros((NV, NC), np.float32)
    configs[:nv, :ncfg] = (r.uniform(size=(nv, ncfg)) < 0.5)
    ustar = np.zeros(NT, np.float32)
    ustar[:nt] = r.uniform(1.0, 10.0, nt)

    got = np.asarray(config_utils(needs, count, qutil, qtenant, configs, ustar))
    want = np.asarray(config_utils_ref(
        *(jnp.asarray(a) for a in (needs, count, qutil, qtenant, configs, ustar))))
    assert_allclose(got, want, rtol=1e-5, atol=1e-6)


def test_config_utils_all_or_nothing_semantics():
    """A query needing two views gets utility only when both are cached."""
    needs = np.zeros((NQ, NV), np.float32)
    needs[0, 0] = needs[0, 1] = 1.0
    count = needs.sum(axis=1).astype(np.float32)
    qutil = np.zeros(NQ, np.float32)
    qutil[0] = 7.0
    qtenant = np.zeros((NT, NQ), np.float32)
    qtenant[0, 0] = 1.0
    configs = np.zeros((NV, NC), np.float32)
    configs[0, 0] = 1.0                      # config 0: only view 0
    configs[0, 1] = configs[1, 1] = 1.0      # config 1: both views
    ustar = np.zeros(NT, np.float32)
    ustar[0] = 7.0
    v = np.asarray(config_utils(needs, count, qutil, qtenant, configs, ustar))
    assert v[0, 0] == 0.0
    assert v[0, 1] == pytest.approx(1.0)


def test_pf_step_improves_objective():
    """A gradient step from a suboptimal point must not decrease g."""
    x, v, wl, cmask, steps = random_pf_inputs(7)

    def g(xv):
        u = v @ xv
        act = wl > 0
        return float((wl[act] * np.log(np.maximum(u[act], 1e-9))).sum()
                     - wl.sum() * xv.sum())

    x1 = np.asarray(pf_step(x, v, wl, cmask, steps))
    assert g(x1) >= g(x) - 1e-6


def test_mmf_step_downweights_satisfied_tenant():
    v = np.zeros((NT, NC), np.float32)
    v[0, 0] = 1.0   # tenant 0 fully satisfied by config 0
    v[1, 1] = 1.0
    tmask = np.zeros(NT, np.float32)
    tmask[:2] = 1.0
    w = np.asarray([0.9, 0.1] + [0.0] * (NT - 2), np.float32)
    w1, pick = mmf_step(w, v, tmask, 0.5)
    w1 = np.asarray(w1)
    assert np.asarray(pick)[0] == 1.0   # config 0 wins for w
    # Tenant 0 (satisfied) loses relative weight: ratio 9 → ·exp(−0.5) ≈ 5.46.
    assert w1[0] / w1[1] < w[0] / w[1]
    assert w1[0] / w1[1] == pytest.approx(9.0 * np.exp(-0.5), rel=1e-4)


@settings(max_examples=15, deadline=None)
@given(seed=st.integers(0, 2**31 - 1), n_active=st.integers(1, NT))
def test_welfare_batch_matches_ref(seed, n_active):
    from compile.kernels import KW
    from compile.kernels.welfare_batch import welfare_batch
    from compile.kernels.ref import welfare_batch_ref

    r = rng(seed)
    v = np.zeros((NT, NC), np.float32)
    v[:n_active, :16] = r.uniform(0.0, 1.0, (n_active, 16))
    cmask = np.zeros(NC, np.float32)
    cmask[:16] = 1.0
    w = np.zeros((KW, NT), np.float32)
    w[:, :n_active] = r.uniform(0.0, 1.0, (KW, n_active))
    got = np.asarray(welfare_batch(w, v, cmask))
    want = np.asarray(welfare_batch_ref(
        jnp.asarray(w), jnp.asarray(v), jnp.asarray(cmask)))
    assert_allclose(got, want)
    # One pick per row, always a live config.
    assert (got.sum(axis=1) == 1.0).all()
    assert (got[:, 16:] == 0).all()
