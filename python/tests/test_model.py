"""L2 solver graphs: behaviour on the paper's canonical instances
(Tables 2/4/5) embedded into the padded shapes."""

import numpy as np
import pytest
from numpy.testing import assert_allclose

from compile.kernels import NC, NT
from compile.model import mmf_mw, pf_solve


def embed(v_small):
    """Place a small [n, m] utility matrix into the padded [NT, NC]."""
    n, m = len(v_small), len(v_small[0])
    v = np.zeros((NT, NC), np.float32)
    v[:n, :m] = np.asarray(v_small, np.float32)
    wl = np.zeros(NT, np.float32)
    wl[:n] = 1.0
    cmask = np.zeros(NC, np.float32)
    cmask[:m] = 1.0
    return v, wl, cmask


def expected_v(v, x):
    return v @ x


def test_pf_solve_table2():
    """Three tenants each wanting a different unit view → x = 1/3 each."""
    v, wl, cmask = embed([[1, 0, 0], [0, 1, 0], [0, 0, 1]])
    x = np.asarray(pf_solve(v, wl, cmask))
    assert x.sum() == pytest.approx(1.0, abs=1e-5)
    assert_allclose(x[:3], [1 / 3] * 3, atol=5e-3)
    assert (x[3:] == 0).all()


def test_pf_solve_table4_core():
    """N−1 tenants want R, one wants S → x_R = (N−1)/N (the core point;
    §3.3). With N = 4: x = (0.75, 0.25)."""
    v, wl, cmask = embed([[1, 0], [1, 0], [1, 0], [0, 1]])
    x = np.asarray(pf_solve(v, wl, cmask))
    assert x[0] == pytest.approx(0.75, abs=5e-3)
    assert x[1] == pytest.approx(0.25, abs=5e-3)


def test_pf_solve_table5():
    """Exact PF optimum x_S = 1/1.98 ≈ 0.50505 (see rust fastpf tests)."""
    v, wl, cmask = embed([[0, 1], [1, 0.01]])
    x = np.asarray(pf_solve(v, wl, cmask))
    assert x[1] == pytest.approx(0.50505, abs=5e-3)


def test_pf_solve_weighted():
    """Doubling a tenant's weight doubles its share in the two-tenant
    disjoint-views instance (weighted PF: x_i ∝ λ_i)."""
    v, wl, cmask = embed([[1, 0], [0, 1]])
    wl[0] = 2.0
    x = np.asarray(pf_solve(v, wl, cmask))
    assert x[0] == pytest.approx(2 / 3, abs=5e-3)
    assert x[1] == pytest.approx(1 / 3, abs=5e-3)


def test_pf_solve_degenerate_no_tenants():
    v = np.zeros((NT, NC), np.float32)
    wl = np.zeros(NT, np.float32)
    cmask = np.zeros(NC, np.float32)
    cmask[:4] = 1.0
    x = np.asarray(pf_solve(v, wl, cmask))
    assert np.isfinite(x).all()
    assert x.sum() == pytest.approx(1.0, abs=1e-4)


def test_mmf_mw_table4_half_half():
    """SIMPLEMMF equalizes: min-rate ≈ 1/2 on Table 4 (N = 4)."""
    v, wl, cmask = embed([[1, 0], [1, 0], [1, 0], [0, 1]])
    x = np.asarray(mmf_mw(v, wl, cmask))
    rates = expected_v(v, x)
    assert x.sum() == pytest.approx(1.0, abs=1e-4)
    assert rates[:4].min() >= 0.5 * 0.85, rates[:4]


def test_mmf_mw_table2():
    v, wl, cmask = embed([[1, 0, 0], [0, 1, 0], [0, 0, 1]])
    x = np.asarray(mmf_mw(v, wl, cmask))
    rates = expected_v(v, x)
    assert rates[:3].min() >= (1 / 3) * 0.85, rates[:3]


def test_mmf_mw_ignores_dead_configs():
    """Padded (masked-out) configs must receive zero mass even if their
    (padding) utility columns were nonzero garbage."""
    v, wl, cmask = embed([[1, 0], [0, 1]])
    v[0, 5] = 99.0  # garbage outside the mask
    x = np.asarray(mmf_mw(v, wl, cmask))
    assert x[5] == 0.0
    assert x[:2].sum() == pytest.approx(1.0, abs=1e-4)
