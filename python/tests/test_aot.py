"""AOT pipeline: every entry point lowers to parseable HLO text with the
expected parameter arity, and the manifest matches."""

import json
import os

import pytest

from compile import aot, model


@pytest.fixture(scope="module")
def artifacts(tmp_path_factory):
    out = tmp_path_factory.mktemp("artifacts")
    manifest = aot.emit(str(out))
    return str(out), manifest


def test_all_entries_emitted(artifacts):
    out, manifest = artifacts
    for name in model.ENTRY_POINTS:
        path = os.path.join(out, f"{name}.hlo.txt")
        assert os.path.exists(path), name
        text = open(path).read()
        assert text.startswith("HloModule"), text[:40]
        assert "ENTRY" in text
        assert manifest["entries"][name]["bytes"] == len(text)


def test_parameter_arity_matches_examples(artifacts):
    out, _ = artifacts
    for name, args in model.example_args().items():
        text = open(os.path.join(out, f"{name}.hlo.txt")).read()
        # The entry computation takes exactly len(args) parameters.
        entry = text[text.index("ENTRY"):]
        first_line = entry.splitlines()[0]
        n_params = first_line.count("parameter_count") or first_line.count("f32[")
        # Parameter declarations appear as %Arg_k or parameter(k); count
        # the distinct parameter(k) instructions in the entry computation.
        param_ids = {
            line.split("parameter(")[1].split(")")[0]
            for line in entry.splitlines()
            if "parameter(" in line
        }
        assert len(param_ids) == len(args), (name, param_ids, n_params)


def test_manifest_round_trips(artifacts):
    out, manifest = artifacts
    loaded = json.load(open(os.path.join(out, "manifest.json")))
    assert loaded == manifest
    shapes = loaded["shapes"]
    assert shapes["NT"] == 16 and shapes["NC"] == 64
    assert shapes["PF_ITERS"] == model.PF_ITERS


def test_lowering_is_deterministic():
    a = aot.to_hlo_text(aot.lower_entry("config_utils"))
    b = aot.to_hlo_text(aot.lower_entry("config_utils"))
    assert a == b
