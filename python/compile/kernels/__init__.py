"""Layer-1 Pallas kernels (interpret=True for CPU-PJRT execution).

Fixed padded shapes shared by every kernel, the L2 graphs, and the Rust
runtime marshalling code (rust/src/runtime/):

- ``NT`` = 16 tenants,
- ``NC`` = 64 candidate configurations (the pruned space of 4.3),
- ``NQ`` = 128 aggregated query classes,
- ``NV`` = 64 candidate views,
- ``LS`` = 8 geometric line-search step candidates per PF iteration,
- ``KW`` = 64 batched weight vectors for welfare scoring.
"""

NT = 16
NC = 64
NQ = 128
NV = 64
LS = 8
KW = 64

EPS = 1e-9
