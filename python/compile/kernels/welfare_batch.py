"""Pallas kernel: batched restricted WELFARE scoring — K dual weight
vectors scored against the scaled-utility matrix in one MXU matmul,
with a masked per-row argmax returning one-hot configuration picks.

This is the §4.3 configuration-pruning inner product (and the scoring
step of any restricted MW iteration) evaluated for a whole sweep of
weight vectors at once: scores = W @ V is a (KW x NT)(NT x NC)
contraction; dead configurations are excluded via cmask before the
argmax.
"""

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from . import KW, NC, NT


def _welfare_batch_kernel(w_ref, v_ref, cmask_ref, out_ref):
    w = w_ref[...]          # [KW, NT]
    v = v_ref[...]          # [NT, NC]
    cmask = cmask_ref[...]  # [NC]

    scores = w @ v          # [KW, NC] — MXU matmul
    scores = scores - (1.0 - cmask)[None, :] * 1e9
    best = jnp.argmax(scores, axis=1)  # [KW]
    cols = jax.lax.broadcasted_iota(jnp.int32, (KW, NC), 1)
    out_ref[...] = (cols == best[:, None]).astype(jnp.float32)


@jax.jit
def welfare_batch(w, v, cmask):
    """One-hot winning configuration per weight vector row."""
    assert w.shape == (KW, NT) and v.shape == (NT, NC) and cmask.shape == (NC,)
    return pl.pallas_call(
        _welfare_batch_kernel,
        out_shape=jax.ShapeDtypeStruct((KW, NC), jnp.float32),
        interpret=True,
    )(w, v, cmask)
