"""Pure-jnp oracles for the Pallas kernels — the correctness ground
truth the pytest suite asserts against (``assert_allclose``).

Each function mirrors one kernel's contract exactly, written in the most
obvious jnp style (no fusion tricks) so a reviewer can audit semantics.
"""

import jax.numpy as jnp

from . import EPS


def pf_step_ref(x, v, wl, cmask, steps):
    """One FASTPF projected-gradient step with geometric line search.

    Program 2's objective g(x) = sum_i wl_i*log(V_i(x)) - L*||x|| with
    L = sum(wl); V_i(x) = (V @ x)_i.

    Args:
      x: f32[NC] current allocation (non-negative, masked by cmask).
      v: f32[NT, NC] scaled utility matrix V_i(S).
      wl: f32[NT] tenant weights (0 for inactive/padded tenants).
      cmask: f32[NC] 1 for live configurations.
      steps: f32[LS] candidate step sizes (step[0] must be 0 = "stay").

    Returns:
      x_next: f32[NC] the best candidate (including "stay").
    """
    total_w = jnp.sum(wl)

    def objective(xc):
        u = v @ xc
        logs = jnp.where(wl > 0.0, jnp.log(jnp.maximum(u, EPS)), 0.0)
        return jnp.sum(wl * logs) - total_w * jnp.sum(xc)

    u = v @ x
    ratio = jnp.where(wl > 0.0, wl / jnp.maximum(u, EPS), 0.0)
    grad = ratio @ v - total_w

    cands = jnp.maximum(x[None, :] + steps[:, None] * grad[None, :], 0.0)
    cands = cands * cmask[None, :]
    objs = jnp.stack([objective(cands[j]) for j in range(cands.shape[0])])
    best = jnp.argmax(objs)
    return cands[best]


def mmf_step_ref(w, v, tmask, eps_mw):
    """One SIMPLEMMF (Algorithm 2) iteration over the pruned space.

    Args:
      w: f32[NT] current dual weights (0 on inactive tenants).
      v: f32[NT, NC] scaled utilities; padded configs must be all-zero
        columns *with* a -inf guard applied via cmask in the caller —
        here the restricted WELFARE argmax treats every column equally,
        so callers zero-pad V and rely on live columns dominating. To be
        exact we take cmask from v: a column with all zeros can still be
        picked if every live column scores 0, which is harmless (caches
        nothing).
      tmask: f32[NT] 1 for active tenants.
      eps_mw: scalar epsilon of the multiplicative update.

    Returns:
      (w_next: f32[NT], chosen: f32[NC] one-hot of the selected config).
    """
    scores = w @ v
    best = jnp.argmax(scores)
    onehot = jnp.zeros(v.shape[1], v.dtype).at[best].set(1.0)
    vi = v[:, best]
    w_next = w * jnp.exp(-eps_mw * vi) * tmask
    norm = jnp.sum(w_next)
    w_next = jnp.where(norm > 0.0, w_next / jnp.maximum(norm, EPS), w)
    return w_next, onehot


def config_utils_ref(needs, need_count, qutil, qtenant, configs, ustar):
    """The all-or-nothing utility matrix evaluation (§5.1 / [9]).

    sat[q, c]  = 1 iff configuration c covers all views of query class q
    U[i, c]    = sum_q qtenant[i, q] * qutil[q] * sat[q, c]
    V[i, c]    = U[i, c] / max(ustar[i], EPS)

    Args:
      needs: f32[NQ, NV] 0/1 required-view incidence per query class.
      need_count: f32[NQ] row sums of `needs` (0 rows = padding).
      qutil: f32[NQ] utility (I/O savings) of each class.
      qtenant: f32[NT, NQ] one-hot tenant ownership.
      configs: f32[NV, NC] 0/1 view membership per configuration.
      ustar: f32[NT] solo-optimal utilities U_i* (0 = inactive tenant).

    Returns:
      v: f32[NT, NC] the scaled utility matrix.
    """
    covered = needs @ configs  # [NQ, NC] - how many required views cached
    sat = (covered >= need_count[:, None] - 0.5).astype(needs.dtype)
    # Padded rows (need_count == 0) are always "satisfied"; kill them via
    # qutil == 0 padding (callers zero-pad qutil).
    u = qtenant @ (sat * qutil[:, None])  # [NT, NC]
    return u / jnp.maximum(ustar, EPS)[:, None]


def welfare_batch_ref(w, v, cmask):
    """Reference for the batched restricted-WELFARE argmax kernel."""
    scores = w @ v - (1.0 - cmask)[None, :] * 1e9
    best = jnp.argmax(scores, axis=1)
    kw, nc = w.shape[0], v.shape[1]
    return (jnp.arange(nc)[None, :] == best[:, None]).astype(w.dtype)
