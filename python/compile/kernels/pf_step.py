"""Pallas kernel: one fused FASTPF projected-gradient step.

The whole solver state — V (16x64 f32 = 4 KiB), the allocation vector,
the gradient, and the LS x NC candidate block — fits in a single VMEM
tile, so the kernel uses one BlockSpec covering each operand (no grid).
The line-search evaluation is shaped as a (LS, NC) x (NC, NT) matmul so
it feeds the MXU as one batched contraction instead of LS sequential
matvecs; the gradient is the dual contraction (NT,) x (NT, NC).

TPU mapping (DESIGN.md §Hardware-Adaptation): the paper's solver is
host-side CPU code; here the entire per-batch solve becomes one
VMEM-resident kernel iterated by `lax.fori_loop` in the L2 graph, so the
Rust hot path makes exactly one PJRT call per batch.
"""

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from . import EPS, LS, NC, NT


def _pf_step_kernel(x_ref, v_ref, wl_ref, cmask_ref, steps_ref, out_ref):
    x = x_ref[...]          # [NC]
    v = v_ref[...]          # [NT, NC]
    wl = wl_ref[...]        # [NT]
    cmask = cmask_ref[...]  # [NC]
    steps = steps_ref[...]  # [LS]
    total_w = jnp.sum(wl)

    # Gradient of g at x: (wl / (V x)) @ V - total_w.
    u = v @ x
    ratio = jnp.where(wl > 0.0, wl / jnp.maximum(u, EPS), 0.0)
    grad = ratio @ v - total_w

    # Geometric line search, evaluated as one batched contraction.
    cands = jnp.maximum(x[None, :] + steps[:, None] * grad[None, :], 0.0)
    cands = cands * cmask[None, :]
    u_cand = cands @ v.T  # [LS, NT] — MXU matmul
    logs = jnp.where(wl[None, :] > 0.0,
                     jnp.log(jnp.maximum(u_cand, EPS)), 0.0)
    objs = logs @ wl - total_w * jnp.sum(cands, axis=1)  # [LS]

    best = jnp.argmax(objs)
    out_ref[...] = cands[best]


@functools.partial(jax.jit, static_argnames=())
def pf_step(x, v, wl, cmask, steps):
    """One PF gradient step (see `_pf_step_kernel`). Shapes fixed to the
    padded NT/NC/LS constants."""
    assert x.shape == (NC,) and v.shape == (NT, NC)
    assert wl.shape == (NT,) and cmask.shape == (NC,) and steps.shape == (LS,)
    return pl.pallas_call(
        _pf_step_kernel,
        out_shape=jax.ShapeDtypeStruct((NC,), jnp.float32),
        interpret=True,  # CPU-PJRT executable; Mosaic lowering is TPU-only
    )(x, v, wl, cmask, steps)
