"""Pallas kernel: batched evaluation of the scaled-utility matrix
V[i, S] for a whole batch of query classes x candidate configurations —
the all-or-nothing utility model of §5.1/[9] as two MXU matmuls:

  sat = (needs @ configs == need_count)   # [NQ, NC] coverage test
  U   = qtenant @ (sat * qutil)           # [NT, NC] tenant aggregation
  V   = U / U*                            # scaled

This is the utility-estimation hot spot of Figure 2 step 2: one kernel
call evaluates every (tenant, configuration) pair at once, replacing the
nested per-config loops a host implementation would run.

VMEM footprint: needs (128x64x4 B = 32 KiB) + configs (16 KiB) +
intermediates — comfortably below the ~16 MiB VMEM budget in one tile,
so a single BlockSpec-less invocation is the right schedule; the two
matmuls are (128x64)x(64x64) and (16x128)x(128x64) MXU contractions.
"""

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from . import EPS, NC, NQ, NT, NV


def _config_utils_kernel(
    needs_ref, count_ref, qutil_ref, qtenant_ref, configs_ref, ustar_ref, out_ref
):
    needs = needs_ref[...]      # [NQ, NV]
    count = count_ref[...]      # [NQ]
    qutil = qutil_ref[...]      # [NQ]
    qtenant = qtenant_ref[...]  # [NT, NQ]
    configs = configs_ref[...]  # [NV, NC]
    ustar = ustar_ref[...]      # [NT]

    covered = needs @ configs   # [NQ, NC] — MXU matmul 1
    sat = (covered >= count[:, None] - 0.5).astype(jnp.float32)
    valued = sat * qutil[:, None]
    u = qtenant @ valued        # [NT, NC] — MXU matmul 2
    out_ref[...] = u / jnp.maximum(ustar, EPS)[:, None]


@jax.jit
def config_utils(needs, need_count, qutil, qtenant, configs, ustar):
    """Scaled utility matrix V[NT, NC]; see module docs for shapes."""
    assert needs.shape == (NQ, NV) and configs.shape == (NV, NC)
    assert qtenant.shape == (NT, NQ)
    return pl.pallas_call(
        _config_utils_kernel,
        out_shape=jax.ShapeDtypeStruct((NT, NC), jnp.float32),
        interpret=True,
    )(needs, need_count, qutil, qtenant, configs, ustar)
