"""Pallas kernel: one fused SIMPLEMMF (Algorithm 2) iteration over the
pruned configuration space.

The restricted WELFARE step is the matvec w @ V followed by a masked
argmax; the multiplicative update re-weights tenants by exp(-eps*V_i(S)).
Everything is VMEM-resident (V is 4 KiB); one kernel invocation per MW
iteration, iterated by `lax.fori_loop` in the L2 graph.
"""

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from . import EPS, NC, NT


def _mmf_step_kernel(w_ref, v_ref, tmask_ref, eps_ref, w_out_ref, pick_ref):
    w = w_ref[...]          # [NT]
    v = v_ref[...]          # [NT, NC]
    tmask = tmask_ref[...]  # [NT]
    eps_mw = eps_ref[0]

    scores = w @ v          # [NC] — restricted WELFARE(w)
    best = jnp.argmax(scores)
    onehot = (jax.lax.broadcasted_iota(jnp.int32, (NC,), 0) == best).astype(
        jnp.float32
    )
    vi = v[:, best]
    w_next = w * jnp.exp(-eps_mw * vi) * tmask
    norm = jnp.sum(w_next)
    w_next = jnp.where(norm > 0.0, w_next / jnp.maximum(norm, EPS), w)

    w_out_ref[...] = w_next
    pick_ref[...] = onehot


@jax.jit
def mmf_step(w, v, tmask, eps_mw):
    """One MW iteration; returns (w_next, one-hot config pick)."""
    assert w.shape == (NT,) and v.shape == (NT, NC) and tmask.shape == (NT,)
    eps_arr = jnp.asarray([eps_mw], jnp.float32)
    return pl.pallas_call(
        _mmf_step_kernel,
        out_shape=(
            jax.ShapeDtypeStruct((NT,), jnp.float32),
            jax.ShapeDtypeStruct((NC,), jnp.float32),
        ),
        interpret=True,
    )(w, v, tmask, eps_arr)
