"""AOT lowering: jit → StableHLO → XlaComputation → **HLO text**.

HLO text (NOT ``lowered.compiler_ir("hlo").as_serialized_hlo_module_proto()``)
is the interchange format: jax ≥ 0.5 emits protos with 64-bit instruction
ids which the runtime's xla_extension 0.5.1 rejects (``proto.id() <=
INT_MAX``); the text parser reassigns ids and round-trips cleanly. See
/opt/xla-example/README.md.

Usage:  cd python && python -m compile.aot --out-dir ../artifacts
Emits one ``<entry>.hlo.txt`` per entry point plus a ``manifest.json``
recording shapes so the Rust runtime can validate its marshalling.
"""

import argparse
import hashlib
import json
import os

import jax
from jax._src.lib import xla_client as xc

from . import model


def to_hlo_text(lowered) -> str:
    """Convert a jax lowering to XLA HLO text via StableHLO."""
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    return comp.as_hlo_text()


def lower_entry(name: str):
    fn = model.ENTRY_POINTS[name]
    args = model.example_args()[name]
    return jax.jit(fn).lower(*args)


def emit(out_dir: str) -> dict:
    os.makedirs(out_dir, exist_ok=True)
    manifest = {"entries": {}}
    for name in model.ENTRY_POINTS:
        lowered = lower_entry(name)
        text = to_hlo_text(lowered)
        path = os.path.join(out_dir, f"{name}.hlo.txt")
        with open(path, "w") as f:
            f.write(text)
        args = model.example_args()[name]
        manifest["entries"][name] = {
            "file": f"{name}.hlo.txt",
            "inputs": [list(a.shape) for a in args],
            "sha256": hashlib.sha256(text.encode()).hexdigest()[:16],
            "bytes": len(text),
        }
        print(f"wrote {path} ({len(text)} chars)")
    manifest["shapes"] = {
        "NT": 16,
        "NC": 64,
        "NQ": 128,
        "NV": 64,
        "LS": 8,
        "PF_ITERS": model.PF_ITERS,
        "MMF_ITERS": model.MMF_ITERS,
        "MMF_EPS": model.MMF_EPS,
    }
    mpath = os.path.join(out_dir, "manifest.json")
    with open(mpath, "w") as f:
        json.dump(manifest, f, indent=2, sort_keys=True)
    print(f"wrote {mpath}")
    return manifest


def main() -> None:
    parser = argparse.ArgumentParser(description=__doc__)
    parser.add_argument("--out-dir", default="../artifacts")
    parser.add_argument(
        "--out", default=None, help="legacy single-file alias (ignored name, uses dir)"
    )
    args = parser.parse_args()
    out_dir = args.out_dir
    if args.out is not None:
        out_dir = os.path.dirname(args.out) or "."
    emit(out_dir)


if __name__ == "__main__":
    main()
