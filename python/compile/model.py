"""Layer 2: the per-batch solver graphs, built from the Layer-1 kernels
and lowered once by :mod:`compile.aot` into self-contained HLO modules.

Three entry points (all fixed-shape, mask-driven):

- :func:`pf_solve` — the full FASTPF solve: ``PF_ITERS`` fused
  gradient-step kernel invocations inside one ``lax.fori_loop``, then a
  final normalization to ``||x|| = 1``. One PJRT call per batch.
- :func:`mmf_mw` — SIMPLEMMF (Algorithm 2) restricted to the pruned
  space: ``MMF_ITERS`` kernel steps accumulating the config histogram.
- :func:`config_utils_model` — the scaled-utility matrix evaluation.
"""

import jax
import jax.numpy as jnp

from .kernels import EPS, KW, LS, NC, NQ, NT, NV
from .kernels.config_utils import config_utils
from .kernels.mmf_step import mmf_step
from .kernels.pf_step import pf_step
from .kernels.welfare_batch import welfare_batch

# Iteration counts baked into the artifacts (one compiled executable per
# variant; see DESIGN.md §Hardware-Adaptation on solver-in-one-artifact).
PF_ITERS = 192
MMF_ITERS = 256
MMF_EPS = 0.2

# Geometric line-search ladder: steps[0] = 0 ("stay"), then step0·decay^j.
PF_STEP0 = 2.0
PF_DECAY = 0.35


def pf_line_search_steps():
    """The fixed LS-long step ladder, first entry 0 (keep current x)."""
    geo = PF_STEP0 * (PF_DECAY ** jnp.arange(LS - 1, dtype=jnp.float32))
    return jnp.concatenate([jnp.zeros((1,), jnp.float32), geo])


def pf_solve(v, wl, cmask):
    """FASTPF over a pruned space.

    Args:
      v: f32[NT, NC] scaled utilities (zero rows/cols for padding).
      wl: f32[NT] tenant weights; 0 disables a tenant.
      cmask: f32[NC] 1 for live configurations.

    Returns:
      x: f32[NC] the PF allocation, normalized to sum 1 over live
        configs (all-zero input degenerates to uniform-over-live).
    """
    steps = pf_line_search_steps()
    live = jnp.maximum(jnp.sum(cmask), 1.0)
    x0 = cmask / live

    def body(_, x):
        return pf_step(x, v, wl, cmask, steps)

    x = jax.lax.fori_loop(0, PF_ITERS, body, x0)
    norm = jnp.sum(x)
    return jnp.where(norm > EPS, x / jnp.maximum(norm, EPS), x0)


def mmf_mw(v, tmask, cmask):
    """SIMPLEMMF over a pruned space (Algorithm 2).

    Args:
      v: f32[NT, NC] scaled utilities.
      tmask: f32[NT] active-tenant mask.
      cmask: f32[NC] live-config mask.

    Returns:
      x: f32[NC] the averaged MW allocation (sums to 1 over live
        configs).
    """
    n_active = jnp.maximum(jnp.sum(tmask), 1.0)
    w0 = tmask / n_active
    # Dead configs must never win the argmax: mask V's columns hard.
    v_masked = v * cmask[None, :] - (1.0 - cmask)[None, :] * 1e9

    def body(_, carry):
        w, x = carry
        w_next, pick = mmf_step(w, v_masked, tmask, MMF_EPS)
        return w_next, x + pick / MMF_ITERS

    _, x = jax.lax.fori_loop(
        0, MMF_ITERS, body, (w0, jnp.zeros((NC,), jnp.float32))
    )
    return x


def config_utils_model(needs, need_count, qutil, qtenant, configs, ustar):
    """Scaled-utility matrix V[NT, NC] (thin wrapper over the kernel)."""
    return config_utils(needs, need_count, qutil, qtenant, configs, ustar)


def welfare_batch_model(w, v, cmask):
    """Batched restricted WELFARE: one-hot winning config per weight row
    (the §4.3 pruning sweep as a single MXU contraction)."""
    return welfare_batch(w, v, cmask)


def example_args():
    """ShapeDtypeStructs for AOT lowering of each entry point."""
    f32 = jnp.float32
    s = jax.ShapeDtypeStruct
    return {
        "pf_solve": (s((NT, NC), f32), s((NT,), f32), s((NC,), f32)),
        "mmf_mw": (s((NT, NC), f32), s((NT,), f32), s((NC,), f32)),
        "config_utils": (
            s((NQ, NV), f32),
            s((NQ,), f32),
            s((NQ,), f32),
            s((NT, NQ), f32),
            s((NV, NC), f32),
            s((NT,), f32),
        ),
        "welfare_batch": (s((KW, NT), f32), s((NT, NC), f32), s((NC,), f32)),
    }


ENTRY_POINTS = {
    "pf_solve": pf_solve,
    "mmf_mw": mmf_mw,
    "config_utils": config_utils_model,
    "welfare_batch": welfare_batch_model,
}
