"""Build-time compile path for ROBUS (never imported at runtime).

Layer 2 (JAX solver graphs) lives in :mod:`compile.model`; Layer 1
(Pallas kernels) in :mod:`compile.kernels`; AOT lowering to HLO text in
:mod:`compile.aot`. The Rust coordinator loads the emitted
``artifacts/*.hlo.txt`` via PJRT and never touches Python.
"""
