//! Domain scenario: the effect of data sharing among tenants
//! (§5.3.1, Figures 5/6, Tables 15-22) at reduced scale.
//!
//! Run: `cargo run --release --example data_sharing [-- --full]`

use robus::experiments::report::appendix_table;
use robus::experiments::runner::run_experiment;
use robus::experiments::setups;

fn main() {
    let full = std::env::args().any(|a| a == "--full");
    println!("=== Effect of data sharing (Sales workload, G1..G4) ===\n");
    for setup in setups::data_sharing_sales() {
        let setup = if full { setup } else { setup.quick(10) };
        let out = run_experiment(&setup);
        println!("{}", appendix_table(&out));
    }
    println!("=== Effect of data sharing (mixed TPC-H + Sales, G1..G4) ===\n");
    for setup in setups::data_sharing_mixed() {
        let setup = if full { setup } else { setup.quick(10) };
        let out = run_experiment(&setup);
        println!("{}", appendix_table(&out));
    }
    println!("Expected shape (paper Figures 5/6): throughput falls with");
    println!("access heterogeneity; STATIC trails on every metric; OPTP");
    println!("tops throughput but drops fairness as sharing increases;");
    println!("MMF/FASTPF hold both.");
}
