//! End-to-end driver (the DESIGN.md §4 validation run): the full system
//! on a real small workload — mixed TPC-H + Sales tenants, batched ROBUS
//! coordination, all four §5.3 policies plus the compiled
//! (JAX/Pallas → HLO → PJRT) FASTPF solver if artifacts are present —
//! reporting the paper's headline metrics (throughput + fairness index)
//! and the per-batch solve latencies. Results are recorded in
//! EXPERIMENTS.md.
//!
//! Run: `make artifacts && cargo run --release --example e2e_cluster`

use robus::alloc::{Policy, PolicyKind};
use robus::coordinator::metrics::MetricsSummary;
use robus::experiments::runner::run_with_policies;
use robus::experiments::{setups, ExperimentSetup};
use robus::runtime::solvers::{AcceleratedFastPf, CompiledSolvers};

fn main() {
    // Mixed G3: two TPC-H tenants + two Sales tenants with distinct
    // skews — the contention-heavy cell of Table 8.
    let setup: ExperimentSetup = setups::data_sharing_mixed().remove(2);
    println!("=== ROBUS end-to-end: {} ===", setup.name);
    println!(
        "{} tenants, {} batches x {}s, 38 candidate views, 6 GB cache\n",
        setup.tenant_specs.len(),
        setup.n_batches,
        setup.batch_secs
    );

    let mut policies: Vec<Box<dyn Policy>> = vec![
        PolicyKind::Static.build(),
        PolicyKind::Mmf.build(),
        PolicyKind::FastPf.build(),
        PolicyKind::Optp.build(),
    ];
    match CompiledSolvers::open_default() {
        Ok(s) => {
            println!("(artifacts found: including the compiled FASTPF-XLA solver)\n");
            policies.push(Box::new(AcceleratedFastPf(s)));
        }
        Err(e) => println!("(no artifacts — native solvers only: {e})\n"),
    }

    let out = run_with_policies(&setup, &policies);

    println!("{}", MetricsSummary::header());
    for s in &out.summaries {
        println!("{}", s.row());
    }

    println!("\nper-policy view-selection latency (host wall-clock):");
    for run in &out.runs {
        let solves: Vec<f64> = run.batches.iter().map(|b| b.solve_secs * 1e3).collect();
        let mean = solves.iter().sum::<f64>() / solves.len().max(1) as f64;
        let max = solves.iter().cloned().fold(0.0, f64::max);
        println!("  {:<12} mean {:>8.2} ms   max {:>8.2} ms", run.policy, mean, max);
    }

    println!("\nqueueing metrics (§5.2):");
    for run in &out.runs {
        println!(
            "  {:<12} mean wait {:>8.1} s   mean flow {:>8.1} s   wait-fairness {:.2}",
            run.policy,
            run.mean_wait(),
            robus::coordinator::metrics::mean_flow_time(run),
            robus::coordinator::metrics::wait_time_fairness(run),
        );
    }

    println!("\nper-tenant mean speedups vs STATIC:");
    for run in out.runs.iter().skip(1) {
        let x = robus::coordinator::metrics::per_tenant_speedups(run, &out.runs[0]);
        let xs: Vec<String> = x.iter().map(|v| format!("{v:.2}")).collect();
        println!("  {:<12} [{}]", run.policy, xs.join(", "));
    }

    // Sanity gates for the recorded run (EXPERIMENTS.md).
    let stat = &out.summaries[0];
    let pf = out
        .summaries
        .iter()
        .find(|s| s.policy == "FASTPF")
        .unwrap();
    assert!(pf.throughput_per_min > stat.throughput_per_min, "FASTPF must beat STATIC");
    assert!(pf.hit_ratio > stat.hit_ratio);
    println!("\nOK: shared fair policies dominate STATIC end-to-end.");
}
