//! Quickstart: the SpaceBook example from the paper's introduction.
//!
//! Three tenants (Analyst, Engineer, VP), three views (R, S, P) of size
//! M each, and a cache of size M (then 2M). Walks through the paper's
//! Scenarios 1-5 and shows how the fair randomized policies produce the
//! "better scenarios" the introduction asks for.
//!
//! Run: `cargo run --release --example quickstart`

use robus::alloc::{Policy, PolicyKind};
use robus::domain::dataset::DatasetCatalog;
use robus::domain::query::{Query, QueryId};
use robus::domain::tenant::{TenantId, TenantSet};
use robus::domain::utility::BatchUtilities;
use robus::domain::view::{ViewCatalog, ViewId, ViewKind};
use robus::util::rng::Pcg64;

const M: u64 = 100; // view size (arbitrary unit)

/// Table 1 of the paper: utilities of cached views to tenants.
///        R  S  P
/// Analyst  2  1  0
/// Engineer 2  1  0
/// VP       0  1  2
fn spacebook(vp_weight: f64, cache: u64) -> (BatchUtilities, Vec<&'static str>) {
    let mut ds = DatasetCatalog::new();
    let mut vc = ViewCatalog::new();
    for name in ["R", "S", "P"] {
        let d = ds.add(name, M);
        vc.add(name, d, ViewKind::BaseTable, M, M);
    }
    let mut ts = TenantSet::new();
    let analyst = ts.add("Analyst", 1.0);
    let engineer = ts.add("Engineer", 1.0);
    let vp = ts.add("VP", vp_weight);

    let mut queries = Vec::new();
    let mut qid = 0;
    let mut push = |t: TenantId, v: usize, util: u64, qs: &mut Vec<Query>| {
        qid += 1;
        qs.push(Query {
            id: QueryId(qid),
            tenant: t,
            arrival: 0.0,
            template: "spacebook".into(),
            required_views: vec![ViewId(v)],
            bytes_read: util,
            compute_cost: 0.0,
        });
    };
    push(analyst, 0, 2, &mut queries);
    push(analyst, 1, 1, &mut queries);
    push(engineer, 0, 2, &mut queries);
    push(engineer, 1, 1, &mut queries);
    push(vp, 1, 1, &mut queries);
    push(vp, 2, 2, &mut queries);

    (
        BatchUtilities::build(&ts, &vc, cache as f64, &queries, None),
        vec!["Analyst", "Engineer", "VP"],
    )
}

fn show(policy: &dyn Policy, batch: &BatchUtilities, names: &[&str]) {
    let mut rng = Pcg64::new(7);
    let alloc = policy.allocate(batch, &mut rng);
    print!("  {:<8}", policy.name());
    for (config, p) in alloc.configs.iter().zip(&alloc.probs) {
        let views: String = ["R", "S", "P"]
            .iter()
            .enumerate()
            .filter(|&(i, _)| config.get(i))
            .map(|(_, n)| *n)
            .collect();
        print!(
            " P[{{{}}}]={:.2}",
            if views.is_empty() { "∅".into() } else { views },
            p
        );
    }
    let v = alloc.expected_scaled_utilities(batch);
    print!("   E[V]: ");
    for (n, vi) in names.iter().zip(&v) {
        print!("{n}={vi:.2} ");
    }
    println!();
}

fn main() {
    println!("=== SpaceBook (paper §1, Table 1) ===\n");

    println!("Scenario 1/2/3 setting: cache = M, weights 1:1:1.5");
    let (batch, names) = spacebook(1.5, M);
    println!("Deterministic weighted utility max would cache R (weighted");
    println!("utility 4 > S's 3.5 > P's 3) and starve the VP — Scenario 3.");
    println!("The randomized fair policies instead:");
    for kind in [PolicyKind::Rsd, PolicyKind::Mmf, PolicyKind::FastPf, PolicyKind::Optp] {
        show(kind.build().as_ref(), &batch, &names);
    }

    println!("\nScenario 4 setting: Zuck doubles the cache (2M).");
    let (batch2, names) = spacebook(1.5, 2 * M);
    println!("Weighted utility max caches {{R,S}} (7.5) — the VP gains little;");
    println!("the paper's 'better scenario' caches {{R,P}}. Fair policies:");
    for kind in [PolicyKind::Mmf, PolicyKind::FastPf, PolicyKind::Optp] {
        show(kind.build().as_ref(), &batch2, &names);
    }

    println!("\nNote how FASTPF spreads probability so every tenant gets its");
    println!("entitled share in expectation (SI), no allocation is Pareto-");
    println!("dominated (PE), and no coalition can do better with its pooled");
    println!("endowment (the randomized core, Theorem 2).");
}
