//! Fairness audit (Table 6): empirically verify SI / PE / core for each
//! mechanism on the paper's canonical instances (Tables 2-5), and show a
//! concrete blocking coalition for MMF on Table 4 (§3.3's "school vs
//! park" example).
//!
//! Run: `cargo run --release --example fairness_audit`

use robus::alloc::instances::{table2, table3, table4, table5};
use robus::alloc::{ConfigSpace, Policy, PolicyKind};
use robus::fairness::properties::{
    find_blocking_coalition, property_report,
};
use robus::util::rng::Pcg64;

fn main() {
    println!("=== Table 6: fairness properties of mechanisms ===\n");
    println!("{:<28} {:>4} {:>4} {:>6}", "Algorithm", "SI", "PE", "CORE");
    for kind in [
        PolicyKind::Lru,
        PolicyKind::Rsd,
        PolicyKind::Optp,
        PolicyKind::Mmf,
        PolicyKind::FastPf,
    ] {
        let policy = kind.build();
        let mut si = true;
        let mut pe = true;
        let mut core = true;
        for batch in [table2(), table3(), table4(4), table5()] {
            let alloc = policy.allocate(&batch, &mut Pcg64::new(0));
            let space = ConfigSpace::pruned(&batch, 100, &mut Pcg64::new(1));
            let rep = property_report(&alloc, &batch, &space, 2e-3);
            si &= rep.sharing_incentive;
            pe &= rep.pareto_efficient;
            core &= rep.core;
        }
        let m = |b: bool| if b { "yes" } else { "-" };
        println!("{:<28} {:>4} {:>4} {:>6}", kind.name(), m(si), m(pe), m(core));
    }

    println!("\n=== Why MMF is outside the core (Table 4, N=4) ===");
    let batch = table4(4);
    let mmf = PolicyKind::Mmf.build();
    let alloc = mmf.allocate(&batch, &mut Pcg64::new(0));
    let v = alloc.expected_scaled_utilities(&batch);
    println!("MMF rates: {v:?} (x_R = x_S = 1/2)");
    let space = ConfigSpace::pruned(&batch, 100, &mut Pcg64::new(1));
    match find_blocking_coalition(&alloc, &batch, &space, 1e-3) {
        Some((coalition, y)) => {
            println!("Blocking coalition: tenants {coalition:?}");
            let total: f64 = y.iter().sum();
            println!(
                "They pool {:.2} of cache probability and all improve: each R-tenant",
                total
            );
            let rates: Vec<f64> = coalition
                .iter()
                .map(|&i| space.scaled_utility(i, &y))
                .collect();
            println!("reaches rates {rates:?} > 1/2 — the 'school' deserves more than");
            println!("half the tax money (§3.3). PF allocates x_R = 3/4 and is unblocked.");
        }
        None => println!("unexpected: no blocking coalition found"),
    }
}
