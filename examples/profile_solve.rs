use robus::alloc::config_space::ConfigSpace;
use robus::alloc::fastpf::FastPf;
use robus::alloc::mmf::MaxMinFair;
use robus::domain::tenant::TenantSet;
use robus::domain::utility::BatchUtilities;
use robus::solver::gradient::GradientConfig;
use robus::util::rng::Pcg64;
use robus::workload::generator::WorkloadGenerator;
use robus::workload::spec::{AccessSpec, TenantSpec, WindowSpec};
use robus::workload::universe::Universe;
use std::time::Instant;

fn main() {
    let u = Universe::mixed();
    let specs = vec![
        TenantSpec::new(AccessSpec::h1(), 20.0),
        TenantSpec::new(AccessSpec::h1(), 20.0),
        TenantSpec::new(AccessSpec::g(1), 20.0).with_window(WindowSpec { mean_secs: 120.0, std_secs: 30.0, candidates: 8 }),
        TenantSpec::new(AccessSpec::g(2), 20.0).with_window(WindowSpec { mean_secs: 120.0, std_secs: 30.0, candidates: 8 }),
    ];
    let mut gen = WorkloadGenerator::new(specs, &u, 42);
    let ts = TenantSet::equal(4);
    // accumulate several batches to find a slow one
    let mut prev = 0.0;
    for b in 1..=12 {
        let t_end = b as f64 * 40.0;
        let queries = gen.generate_until(t_end, &u);
        let _ = prev; prev = t_end;
        if queries.is_empty() { continue; }
        let t0 = Instant::now();
        let batch = BatchUtilities::build(&ts, &u.views, 6.0 * (1u64<<30) as f64, &queries, None);
        let t_build = t0.elapsed();
        let t1 = Instant::now();
        let mut rng = Pcg64::new(7);
        let space = ConfigSpace::pruned(&batch, 50, &mut rng);
        let t_prune = t1.elapsed();
        let t2 = Instant::now();
        let _x = FastPf::solve_over(&space, &batch, &GradientConfig::default());
        let t_pf = t2.elapsed();
        let t3 = Instant::now();
        let _m = MaxMinFair::solve_over(&space, &batch);
        let t_mmf = t3.elapsed();
        println!(
            "batch {b:>2}: q={:<3} classes={:<3} space={:<3} build={:>8.2?} prune={:>8.2?} pf={:>8.2?} mmf={:>8.2?}",
            queries.len(), batch.classes.len(), space.len(), t_build, t_prune, t_pf, t_mmf
        );
    }
}
