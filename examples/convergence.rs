//! Convergence of the randomized policies (Figure 11): fairness index as
//! a function of the number of batches, for MMF and FASTPF on a four
//! tenant Sales workload. The paper observes convergence at ~15-25
//! batches.
//!
//! Run: `cargo run --release --example convergence`

use robus::experiments::runner::{convergence_series, run_experiment};
use robus::experiments::setups;

fn main() {
    let setup = setups::convergence(); // 4 tenants, 50 batches
    println!("=== Figure 11: fairness index vs batches (4 tenants, 50 batches) ===\n");
    let out = run_experiment(&setup);
    let baseline = &out.runs[0];
    let mmf = out.run_for("MMF").unwrap();
    let pf = out.run_for("FASTPF").unwrap();
    let s_mmf = convergence_series(mmf, baseline, 2);
    let s_pf = convergence_series(pf, baseline, 2);
    println!("{:>8} {:>8} {:>8}", "batches", "MMF", "FASTPF");
    for ((b, jm), (_, jp)) in s_mmf.iter().zip(&s_pf) {
        let bar = "*".repeat((jp * 40.0) as usize);
        println!("{b:>8} {jm:>8.3} {jp:>8.3}  {bar}");
    }
    let last = s_pf.last().unwrap().1;
    println!("\nfinal FASTPF fairness index: {last:.3}");
}
